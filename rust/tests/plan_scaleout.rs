//! Scale-out acceptance tests over the public API: the execution-plan
//! layer (replication / layer-splitting across channels × ranks) and the
//! mapping edge cases the plan layer leans on (wide MACs, k clamping, the
//! capacity-wave fallback).

use pim_dram::dram::DramGeometry;
use pim_dram::mapping::{map_layer, map_network, MapConfig, MapError};
use pim_dram::plan::ShardPolicy;
use pim_dram::sim::{simulate, SimConfig};
use pim_dram::util::ceil_div;
use pim_dram::workloads::nets::{alexnet, pimnet, resnet18, vgg16};

// ---- replicated shards scale linearly -------------------------------------

#[test]
fn replicated_shards_scale_throughput_linearly() {
    for net in [pimnet(), alexnet(), resnet18()] {
        let single = simulate(
            &net,
            &SimConfig::conservative(8).with_grid(1, 4),
        )
        .unwrap();
        let per_replica = single.replica_throughput_ips();
        for channels in [2usize, 3, 4] {
            let r = simulate(
                &net,
                &SimConfig::conservative(8).with_grid(channels, 4),
            )
            .unwrap();
            let n = r.replicas() as f64;
            assert!(r.replicas() >= channels, "{}: too few replicas", net.name);
            // Aggregate ≥ (N − ε) × single-module steady-state throughput.
            assert!(
                r.throughput_ips() >= (n - 1e-9) * per_replica,
                "{}: {} replicas gave {:.1} img/s vs {:.1} per replica",
                net.name,
                r.replicas(),
                r.throughput_ips(),
                per_replica
            );
            // And replication never distorts the per-replica pipeline.
            assert!(
                (r.pipeline.cycle_ns - single.pipeline.cycle_ns).abs() < 1e-9,
                "{}: replica cycle moved",
                net.name
            );
        }
    }
}

#[test]
fn rank_slack_packs_extra_replicas_in_one_channel() {
    // pimnet needs 1 of the 4 ranks → 4 replicas on a single channel.
    let r = simulate(&pimnet(), &SimConfig::conservative(8)).unwrap();
    assert_eq!(r.replicas(), 4);
    let one_slot = simulate(
        &pimnet(),
        &SimConfig::conservative(8).with_grid(1, 1),
    )
    .unwrap();
    assert_eq!(one_slot.replicas(), 1);
    let ratio = r.throughput_ips() / one_slot.throughput_ips();
    assert!((ratio - 4.0).abs() < 1e-9, "ratio {ratio}");
}

// ---- layer-split prices inter-channel transfers ---------------------------

#[test]
fn layer_split_latency_strictly_exceeds_single_module() {
    for net in [vgg16(), resnet18(), alexnet()] {
        let single = simulate(
            &net,
            &SimConfig::conservative(8).with_grid(1, 4),
        )
        .unwrap();
        let split = simulate(
            &net,
            &SimConfig::conservative(8)
                .with_grid(2, 4)
                .with_shard(ShardPolicy::LayerSplit),
        )
        .unwrap();
        assert!(split.scale_out.hop_ns_total > 0.0, "{}", net.name);
        assert!(
            split.latency_ns() > single.latency_ns(),
            "{}: layer-split latency {:.1} must exceed single-module {:.1}",
            net.name,
            split.latency_ns(),
            single.latency_ns()
        );
        // The same stages exist — nothing is dropped to win the comparison.
        assert_eq!(
            split.pipeline.stages.len(),
            net.layers.len() + net.residuals.len()
        );
    }
}

#[test]
fn paper_favorable_split_pays_even_more() {
    // Paper-favorable widens *internal* links to row width, so the 64-bit
    // channel hop is relatively much dearer — the latency gap must widen
    // in relative terms.
    let net = vgg16();
    let rel_gap = |mk: fn(usize) -> SimConfig| -> f64 {
        let single = simulate(&net, &mk(8).with_grid(1, 4)).unwrap();
        let split = simulate(
            &net,
            &mk(8).with_grid(2, 4).with_shard(ShardPolicy::LayerSplit),
        )
        .unwrap();
        (split.latency_ns() - single.latency_ns()) / single.latency_ns()
    };
    let fav = rel_gap(SimConfig::paper_favorable);
    let con = rel_gap(SimConfig::conservative);
    assert!(fav > 0.0 && con > 0.0);
    assert!(fav > con, "favorable gap {fav} vs conservative {con}");
}

#[test]
fn hybrid_replicas_match_policy() {
    let r = simulate(
        &alexnet(),
        &SimConfig::conservative(8)
            .with_grid(4, 4)
            .with_shard(ShardPolicy::Hybrid { replicas: 2 }),
    )
    .unwrap();
    assert_eq!(r.replicas(), 2);
    assert_eq!(r.scale_out.devices.len(), 2);
    assert!(r.scale_out.hop_ns_total > 0.0);
    assert!(
        (r.throughput_ips() - 2.0 * r.replica_throughput_ips()).abs()
            < 1e-9 * r.throughput_ips()
    );
}

// ---- mapping edge cases ---------------------------------------------------

#[test]
fn wide_mac_spans_subarrays_even_when_folded() {
    // vgg16 fc6: mac_size 25088 spans ceil(25088/4096) = 7 subarrays; the
    // folding factor k shrinks the group but never splits a MAC.
    let net = vgg16();
    let fc6 = net.layers.iter().position(|l| l.name == "fc6").unwrap();
    for k in [1usize, 2, 8] {
        let cfg = MapConfig::uniform(DramGeometry::paper_default(), 8, k);
        let m = map_layer(fc6, fc6, &net.layers[fc6], &cfg).unwrap();
        assert_eq!(m.subarrays_per_mac, 7, "k={k}");
        assert_eq!(m.macs_per_subarray, 0, "k={k}");
        assert_eq!(m.macs_per_group, ceil_div(4096, k), "k={k}");
        assert_eq!(m.subarrays_ideal, m.macs_per_group * 7, "k={k}");
        assert_eq!(m.waves, ceil_div(m.subarrays_ideal, 32), "k={k}");
    }
}

#[test]
fn k_beyond_filter_count_rejected_then_clamped() {
    // Direct map_layer: k > outer count is an error ...
    let net = pimnet();
    let fc2 = &net.layers[3]; // 10 output neurons
    let cfg = MapConfig::uniform(DramGeometry::paper_default(), 8, 64);
    let err = map_layer(3, 3, fc2, &cfg).unwrap_err();
    assert!(matches!(err, MapError::KTooLarge { k: 64, .. }));
    // ... while map_network clamps a uniform P vector per layer.
    let m = map_network(&net, &cfg).unwrap();
    assert_eq!(m.layers[3].k, 10);
    assert!(m.layers.iter().all(|l| l.k <= 64));
    // The clamped map must also price end to end.
    let sim = simulate(&net, &SimConfig::conservative(8).with_ks(vec![64]));
    assert!(sim.is_ok());
}

#[test]
fn capacity_wave_fallback_covers_the_whole_group() {
    // Starve the bank to one subarray: every group must still be covered,
    // one wave per ideal subarray.
    let mut g = DramGeometry::paper_default();
    g.subarrays_per_bank = 1;
    let net = alexnet();
    let cfg = MapConfig::uniform(g.clone(), 8, 1);
    for (i, layer) in net.layers.iter().enumerate() {
        let m = map_layer(i, i, layer, &cfg).unwrap();
        assert_eq!(m.subarrays_used, 1, "{}", layer.name);
        assert_eq!(m.waves, m.subarrays_ideal, "{}", layer.name);
        assert_eq!(m.rounds(), m.k * m.waves, "{}", layer.name);
    }
    // And the simulator charges the re-staging for it.
    let mut cfg_sim = SimConfig::conservative(8);
    cfg_sim.geometry.subarrays_per_bank = 1;
    let starved = simulate(&net, &cfg_sim).unwrap();
    let healthy = simulate(&net, &SimConfig::conservative(8)).unwrap();
    let restage = |r: &pim_dram::sim::SimResult| -> f64 {
        r.layers.iter().map(|l| l.restage_ns).sum()
    };
    assert!(restage(&starved) > restage(&healthy));
    assert!(starved.latency_ns() > healthy.latency_ns());
}

#[test]
fn plan_surfaces_mapping_errors() {
    // A grid too small for the network fails in the mapping stage and the
    // plan layer reports it as such.
    let mut g = DramGeometry::paper_default();
    g.channels = 1;
    g.ranks_per_channel = 1;
    g.banks_per_rank = 2;
    let mut cfg = SimConfig::conservative(8);
    cfg.geometry = g;
    let err = simulate(&vgg16(), &cfg).unwrap_err();
    assert!(err.to_string().contains("banks"));
}
