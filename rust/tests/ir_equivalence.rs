//! The `pim::ir` migration bar: lowering the four paper networks through
//! the typed operator-graph IR must reproduce the pre-refactor flat
//! layer chains **exactly** — first structurally (the lowered `Network`
//! equals the hand-built chain), then bitwise through the whole pricing
//! stack (`SimResult` and `SimReport`, errors included) across
//! network × preset × shard × grid × ks. Plus: the two new generality
//! workloads (`mobilenet_mini`, `tinyformer`) run end-to-end through
//! `Job::report()` and `Job::serve()`.
//!
//! The flat constructors below are verbatim copies of the pre-IR
//! `workloads::nets` builders — the "pre-refactor path" this test holds
//! the graph lowering to.

use pim_dram::api::{DevicesSpec, Job, ServeSpec, Spec};
use pim_dram::plan::ShardPolicy;
use pim_dram::sim::{simulate, SimConfig, SimResult};
use pim_dram::workloads::{nets, LayerDesc, Network, Residual};

// ---- the pre-refactor flat constructors (frozen) --------------------------

fn legacy_alexnet() -> Network {
    let layers = vec![
        LayerDesc::conv("conv1", (227, 227), 3, 96, 11, 4, 0, true),
        LayerDesc::conv("conv2", (27, 27), 96, 256, 5, 1, 2, true),
        LayerDesc::conv("conv3", (13, 13), 256, 384, 3, 1, 1, false),
        LayerDesc::conv("conv4", (13, 13), 384, 384, 3, 1, 1, false),
        LayerDesc::conv("conv5", (13, 13), 384, 256, 3, 1, 1, true),
        LayerDesc::linear("fc6", 9216, 4096, true),
        LayerDesc::linear("fc7", 4096, 4096, true),
        LayerDesc::linear("fc8", 4096, 1000, false),
    ];
    Network { name: "alexnet".into(), layers, residuals: vec![] }
}

fn legacy_vgg16() -> Network {
    let layers = vec![
        LayerDesc::conv("conv1_1", (224, 224), 3, 64, 3, 1, 1, false),
        LayerDesc::conv("conv1_2", (224, 224), 64, 64, 3, 1, 1, true),
        LayerDesc::conv("conv2_1", (112, 112), 64, 128, 3, 1, 1, false),
        LayerDesc::conv("conv2_2", (112, 112), 128, 128, 3, 1, 1, true),
        LayerDesc::conv("conv3_1", (56, 56), 128, 256, 3, 1, 1, false),
        LayerDesc::conv("conv3_2", (56, 56), 256, 256, 3, 1, 1, false),
        LayerDesc::conv("conv3_3", (56, 56), 256, 256, 3, 1, 1, true),
        LayerDesc::conv("conv4_1", (28, 28), 256, 512, 3, 1, 1, false),
        LayerDesc::conv("conv4_2", (28, 28), 512, 512, 3, 1, 1, false),
        LayerDesc::conv("conv4_3", (28, 28), 512, 512, 3, 1, 1, true),
        LayerDesc::conv("conv5_1", (14, 14), 512, 512, 3, 1, 1, false),
        LayerDesc::conv("conv5_2", (14, 14), 512, 512, 3, 1, 1, false),
        LayerDesc::conv("conv5_3", (14, 14), 512, 512, 3, 1, 1, true),
        LayerDesc::linear("fc6", 25088, 4096, true),
        LayerDesc::linear("fc7", 4096, 4096, true),
        LayerDesc::linear("fc8", 4096, 1000, false),
    ];
    Network { name: "vgg16".into(), layers, residuals: vec![] }
}

fn legacy_resnet18() -> Network {
    let mut layers = vec![LayerDesc::conv("conv1", (224, 224), 3, 64, 7, 2, 3, true)];
    let stages: [(usize, usize, usize); 4] =
        [(56, 64, 1), (56, 128, 2), (28, 256, 2), (14, 512, 2)];
    let mut in_ch = 64;
    for (si, &(hw, ch, stride1)) in stages.iter().enumerate() {
        for block in 0..2 {
            let (s, ic, dim) = if block == 0 {
                (stride1, in_ch, hw)
            } else {
                (1, ch, hw / stride1)
            };
            let out_dim = dim / s;
            layers.push(LayerDesc::conv(
                &format!("l{}b{}c1", si + 1, block + 1),
                (dim, dim),
                ic,
                ch,
                3,
                s,
                1,
                false,
            ));
            layers.push(LayerDesc::conv(
                &format!("l{}b{}c2", si + 1, block + 1),
                (out_dim, out_dim),
                ch,
                ch,
                3,
                1,
                1,
                false,
            ));
        }
        in_ch = ch;
    }
    let last = layers.len() - 1;
    layers[last] = layers[last].clone().with_gap();
    layers.push(LayerDesc::linear("fc", 512, 1000, false));
    let residuals = (0..8)
        .map(|b| Residual { from_layer: 2 * b, into_layer: 2 * b + 2 })
        .collect();
    Network { name: "resnet18".into(), layers, residuals }
}

fn legacy_pimnet() -> Network {
    let layers = vec![
        LayerDesc::conv("conv1", (16, 16), 1, 16, 3, 1, 1, true),
        LayerDesc::conv("conv2", (8, 8), 16, 32, 3, 1, 1, true),
        LayerDesc::linear("fc1", 512, 128, true),
        LayerDesc::linear("fc2", 128, 10, false),
    ];
    Network { name: "pimnet".into(), layers, residuals: vec![] }
}

fn legacy_networks() -> Vec<Network> {
    vec![legacy_alexnet(), legacy_vgg16(), legacy_resnet18(), legacy_pimnet()]
}

// ---- comparison helpers ---------------------------------------------------

/// Bitwise comparison of everything the experiments read.
fn assert_bitwise(ctx: &str, legacy: &SimResult, lowered: &SimResult) {
    assert_eq!(lowered.net_name, legacy.net_name, "{ctx}: net_name");
    assert_eq!(lowered.n_bits, legacy.n_bits, "{ctx}: n_bits");
    assert_eq!(
        lowered.pipeline.latency_ns.to_bits(),
        legacy.pipeline.latency_ns.to_bits(),
        "{ctx}: latency"
    );
    assert_eq!(
        lowered.pipeline.cycle_ns.to_bits(),
        legacy.pipeline.cycle_ns.to_bits(),
        "{ctx}: cycle"
    );
    assert_eq!(
        lowered.pipeline.bottleneck, legacy.pipeline.bottleneck,
        "{ctx}: bottleneck"
    );
    assert_eq!(lowered.total_aaps, legacy.total_aaps, "{ctx}: aaps");
    assert_eq!(
        lowered.total_dram_energy_nj.to_bits(),
        legacy.total_dram_energy_nj.to_bits(),
        "{ctx}: dram energy"
    );
    assert_eq!(
        lowered.logic_energy_nj.to_bits(),
        legacy.logic_energy_nj.to_bits(),
        "{ctx}: logic energy"
    );
    assert_eq!(
        lowered.throughput_ips().to_bits(),
        legacy.throughput_ips().to_bits(),
        "{ctx}: throughput"
    );
    assert_eq!(lowered.replicas(), legacy.replicas(), "{ctx}: replicas");
    assert_eq!(
        lowered.scale_out.hop_ns_total.to_bits(),
        legacy.scale_out.hop_ns_total.to_bits(),
        "{ctx}: hops"
    );
    assert_eq!(lowered.layers.len(), legacy.layers.len(), "{ctx}: layer count");
    for (a, b) in lowered.layers.iter().zip(&legacy.layers) {
        assert_eq!(a.name, b.name, "{ctx}: layer name");
        assert_eq!(a.mapping, b.mapping, "{ctx}: {} mapping", a.name);
        for (va, vb, what) in [
            (a.multiply_ns, b.multiply_ns, "multiply"),
            (a.logic_ns, b.logic_ns, "logic"),
            (a.restage_ns, b.restage_ns, "restage"),
            (a.transfer_ns, b.transfer_ns, "transfer"),
            (a.dram_energy_nj, b.dram_energy_nj, "energy"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{ctx}: {} {}", a.name, what);
        }
        assert_eq!(a.aaps, b.aaps, "{ctx}: {} aaps", a.name);
    }
}

// ---- the bars -------------------------------------------------------------

#[test]
fn graphs_lower_to_the_exact_legacy_networks() {
    for legacy in legacy_networks() {
        let lowered = nets::by_name(&legacy.name).unwrap();
        assert_eq!(
            lowered, legacy,
            "{}: IR lowering diverged from the flat chain",
            legacy.name
        );
    }
}

#[test]
fn lowered_graphs_price_bitwise_identically() {
    let grids = [(1usize, 4usize), (2, 2), (4, 4)];
    let policies = [
        ShardPolicy::Replicate,
        ShardPolicy::LayerSplit,
        ShardPolicy::Hybrid { replicas: 2 },
    ];
    let mut simulated = 0usize;
    let mut failed = 0usize;
    for legacy in legacy_networks() {
        for preset in ["paper_favorable", "conservative"] {
            for (channels, ranks) in grids {
                for policy in policies {
                    for k in [1usize, 2] {
                        let cfg = match preset {
                            "conservative" => SimConfig::conservative(8),
                            _ => SimConfig::paper_favorable(8),
                        }
                        .with_grid(channels, ranks)
                        .with_shard(policy)
                        .with_ks(vec![k]);
                        let ctx = format!(
                            "{} {preset} {channels}x{ranks} {policy} k={k}",
                            legacy.name
                        );
                        // Pre-refactor path: the frozen flat chain through
                        // the free engine entry point.
                        let legacy_r = simulate(&legacy, &cfg);
                        // IR path: builtin graph, lowered, through Job.
                        let job = Job::new(
                            Spec::builtin(&legacy.name)
                                .with_preset(preset)
                                .with_grid(channels, ranks)
                                .with_shard(policy)
                                .with_ks(vec![k]),
                        )
                        .expect("spec resolves");
                        match legacy_r {
                            Err(e) => {
                                assert_eq!(
                                    job.simulate_full().unwrap_err(),
                                    e,
                                    "{ctx}: error equality"
                                );
                                failed += 1;
                            }
                            Ok(legacy_r) => {
                                let lowered =
                                    job.simulate_full().unwrap_or_else(|e| {
                                        panic!("{ctx}: IR path failed: {e}")
                                    });
                                assert_bitwise(&ctx, &legacy_r, &lowered);
                                let rep = job.report().unwrap();
                                assert_eq!(
                                    rep.latency_ns.to_bits(),
                                    legacy_r.pipeline.latency_ns.to_bits(),
                                    "{ctx}: report latency"
                                );
                                assert_eq!(
                                    rep.cycle_ns.to_bits(),
                                    legacy_r.pipeline.cycle_ns.to_bits(),
                                    "{ctx}: report cycle"
                                );
                                assert_eq!(
                                    rep.total_aaps, legacy_r.total_aaps,
                                    "{ctx}: report aaps"
                                );
                                simulated += 1;
                            }
                        }
                    }
                }
            }
        }
    }
    assert!(simulated > 0, "no point simulated");
    assert!(failed > 0, "expected some plan errors in the grid sweep");
}

#[test]
fn generality_workloads_report_end_to_end() {
    for name in ["mobilenet_mini", "tinyformer"] {
        for preset in ["paper_favorable", "conservative"] {
            let job = Job::new(Spec::builtin(name).with_preset(preset)).unwrap();
            let rep = job.report().unwrap_or_else(|e| panic!("{name} {preset}: {e}"));
            assert!(rep.cycle_ns > 0.0 && rep.cycle_ns.is_finite(), "{name}");
            assert!(rep.latency_ns >= rep.cycle_ns, "{name}");
            assert!(rep.replicas >= 1, "{name}");
            assert!(rep.total_aaps > 0, "{name}");
        }
        // Per-layer ks and layer-split lowering also work on the new nets.
        let net = nets::by_name(name).unwrap();
        let ks: Vec<usize> =
            (0..net.layers.len()).map(|i| 1 + (i % 2)).collect();
        let job = Job::new(
            Spec::builtin(name)
                .with_preset("conservative")
                .with_grid(2, 4)
                .with_shard(ShardPolicy::LayerSplit)
                .with_ks(ks),
        )
        .unwrap();
        let rep = job.report().unwrap();
        assert!(rep.hop_ns_total > 0.0, "{name}: split must pay hops");
    }
}

#[test]
fn generality_workloads_serve_end_to_end() {
    for name in ["mobilenet_mini", "tinyformer"] {
        let spec = Spec::builtin(name).with_preset("conservative").with_serve(
            ServeSpec { devices: Some(DevicesSpec::Count(2)), batch: 4, ..ServeSpec::default() },
        );
        let job = Job::new(spec).unwrap();
        let net = job.network().clone();
        let handle = job.serve().unwrap_or_else(|e| panic!("{name}: serve: {e}"));
        assert_eq!(handle.devices, 2, "{name}");
        let elems = handle.server.image_elems();
        assert_eq!(elems, net.layers[0].in_elems(), "{name}: input elems");
        for i in 0..6i32 {
            let resp = handle.server.classify(vec![i; elems]).unwrap();
            assert!(resp.class < resp.logits.len(), "{name}");
        }
        let m = handle.server.metrics();
        assert_eq!(m.requests, 6, "{name}");
        assert_eq!(m.per_device.len(), 2, "{name}");
        handle.server.shutdown();
    }
}

#[test]
fn residuals_are_graph_edges_not_a_side_table() {
    // The tinyformer residuals land on the stages its adds name — proof
    // the edge form survives lowering — and price as reserved-bank
    // stages exactly like the paper CNN's shortcuts.
    let net = nets::tinyformer();
    assert_eq!(net.residuals.len(), 2);
    let r = simulate(&net, &SimConfig::conservative(8)).unwrap();
    let res_stages: Vec<_> = r
        .pipeline
        .stages
        .iter()
        .filter(|s| s.name.starts_with("res:"))
        .collect();
    assert_eq!(res_stages.len(), 2);
    for s in res_stages {
        assert!(s.compute_ns > 0.0 && s.transfer_ns > 0.0, "{}", s.name);
    }
    assert_eq!(
        r.pipeline.stages.len(),
        net.layers.len() + net.residuals.len()
    );
}
