//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they skip (with a notice)
//! otherwise so `cargo test` stays green pre-build. One shared CPU client
//! per process (client creation is the slow part).

use pim_dram::arch::{adder_tree::AdderTree, bank_pim::BankPipeline};
use pim_dram::runtime::{
    artifacts_available, artifacts_dir, ArtifactManifest, DigitsDataset,
    PimNetExecutor, Runtime, Tensor,
};
use pim_dram::util::rng::Rng;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn mvm_artifact_matches_integer_matmul_and_dram_sim() {
    require_artifacts!();
    let dir = artifacts_dir();
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let module = rt.load_hlo_text(&dir.join(&manifest.mvm_hlo)).unwrap();

    let (m, k, n) = manifest.mvm_shape;
    let mut rng = Rng::new(42);
    let x: Vec<i32> = (0..m * k)
        .map(|_| rng.int_range(0, (1 << manifest.wa) - 1) as i32)
        .collect();
    let w: Vec<i32> = (0..k * n)
        .map(|_| {
            rng.int_range(-(1 << (manifest.ww - 1)), (1 << (manifest.ww - 1)) - 1)
                as i32
        })
        .collect();

    // 1) PJRT execution of the AOT'd Pallas bit-serial kernel.
    let out = module
        .run1(&[Tensor::i32(x.clone(), &[m, k]), Tensor::i32(w.clone(), &[k, n])])
        .unwrap();
    let got = out.as_i32().unwrap();

    // 2) Plain integer matmul oracle.
    for i in 0..m {
        for j in 0..n {
            let want: i64 = (0..k)
                .map(|kk| x[i * k + kk] as i64 * w[kk * n + j] as i64)
                .sum();
            assert_eq!(
                got[i * n + j] as i64,
                want,
                "mismatch at ({i},{j})"
            );
        }
    }

    // 3) The Rust bit-level DRAM pipeline (subarray multiply + adder tree
    //    + accumulator + zero-point correction) on the first row — the
    //    three implementations of the paper's §III primitive must agree.
    let bp = BankPipeline::new(AdderTree::new(4096), manifest.ww);
    let x0: Vec<u64> = x[..k].iter().map(|&v| v as u64).collect();
    let w_mat: Vec<Vec<i64>> = (0..k)
        .map(|kk| (0..n).map(|j| w[kk * n + j] as i64).collect())
        .collect();
    let sim = bp.mvm(&x0, &w_mat);
    for j in 0..n {
        assert_eq!(sim[j], got[j] as i64, "DRAM sim mismatch at col {j}");
    }
}

#[test]
fn testvectors_replay_on_pim_subarray() {
    require_artifacts!();
    // Shared vectors emitted by aot.py: the Pallas kernel, the jnp oracle
    // and the Rust bit-level simulator must all agree on them.
    let dir = artifacts_dir();
    let text = std::fs::read_to_string(dir.join("testvectors.json")).unwrap();
    let j = pim_dram::util::json::Json::parse(&text).unwrap();
    let cases = j.req_arr("matmul_cases").unwrap();
    assert!(cases.len() >= 5);
    for case in cases {
        let (m, k, n) = (
            case.req_i64("m").unwrap() as usize,
            case.req_i64("k").unwrap() as usize,
            case.req_i64("n").unwrap() as usize,
        );
        let wa = case.req_i64("wa").unwrap() as usize;
        let ww = case.req_i64("ww").unwrap() as usize;
        let x = case.get("x").unwrap().i64_vec().unwrap();
        let w = case.get("w").unwrap().i64_vec().unwrap();
        let y = case.get("y").unwrap().i64_vec().unwrap();

        let bp = BankPipeline::asymmetric(AdderTree::new(256), wa, ww);
        for i in 0..m {
            let xi: Vec<u64> = x[i * k..(i + 1) * k]
                .iter()
                .map(|&v| v as u64)
                .collect();
            let w_mat: Vec<Vec<i64>> = (0..k)
                .map(|kk| (0..n).map(|j| w[kk * n + j]).collect())
                .collect();
            let got = bp.mvm(&xi, &w_mat);
            for jj in 0..n {
                assert_eq!(got[jj], y[i * n + jj], "case m{m}k{k}n{n} ({i},{jj})");
            }
        }
    }
}

#[test]
fn layer_chain_equals_fused_model() {
    require_artifacts!();
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let exec = PimNetExecutor::load(&rt, &dir).unwrap();
    let ds = DigitsDataset::load(&dir, &exec.manifest).unwrap();
    let (images, _) = ds.batch(0, exec.batch_size());

    let chain = exec.run_chain(images.clone()).unwrap();
    let fused = exec.run_full(images).unwrap();
    assert_eq!(chain.shape(), fused.shape());
    let (a, b) = (chain.as_f32().unwrap(), fused.as_f32().unwrap());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < 1e-3,
            "logit {i}: chain {x} vs fused {y}"
        );
    }
}

#[test]
fn artifact_accuracy_matches_manifest() {
    require_artifacts!();
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let exec = PimNetExecutor::load(&rt, &dir).unwrap();
    let ds = DigitsDataset::load(&dir, &exec.manifest).unwrap();

    let batch = exec.batch_size();
    let n_eval = ds.count.min(32);
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut start = 0;
    while total < n_eval {
        let (images, labels) = ds.batch(start, batch);
        let logits = exec.run_chain(images).unwrap();
        let classes = PimNetExecutor::classify(&logits).unwrap();
        for (c, l) in classes.iter().zip(&labels) {
            if total < n_eval {
                correct += (*c == *l as usize) as usize;
                total += 1;
            }
        }
        start += batch;
    }
    let acc = correct as f64 / total as f64;
    // Python-side quant accuracy was measured on the same pipeline; allow
    // slack for the different eval subset.
    assert!(
        acc + 0.15 >= exec.manifest.quant_test_accuracy,
        "accuracy {acc} vs manifest {}",
        exec.manifest.quant_test_accuracy
    );
}

#[test]
fn layer_shapes_respected() {
    require_artifacts!();
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let exec = PimNetExecutor::load(&rt, &dir).unwrap();
    // Wrong shape must error, not crash.
    let bad = Tensor::i32(vec![0; 4], &[1, 2, 2, 1]);
    assert!(exec.run_layer(0, bad).is_err());
    // Intermediate dtypes: all but last layer produce i32.
    let ds = DigitsDataset::load(&dir, &exec.manifest).unwrap();
    let (images, _) = ds.batch(0, exec.batch_size());
    let shape = &exec.manifest.layers[0].in_shape;
    let mut act = Tensor::i32(images, shape);
    for idx in 0..exec.num_layers() - 1 {
        act = exec.run_layer(idx, act).unwrap();
        assert!(act.as_i32().is_ok(), "layer {idx} must output i32");
        let meta = &exec.manifest.layers[idx];
        assert_eq!(act.shape(), meta.out_shape.as_slice());
        // Quantized range invariant (paper: unsigned n-bit activations).
        let max = *act.as_i32().unwrap().iter().max().unwrap();
        let min = *act.as_i32().unwrap().iter().min().unwrap();
        assert!(min >= 0 && max < (1 << exec.manifest.wa), "layer {idx} range");
    }
    let logits = exec.run_layer(exec.num_layers() - 1, act).unwrap();
    assert!(logits.as_f32().is_ok(), "final layer must output f32 logits");
}

#[test]
fn pimnet_workload_descriptor_matches_manifest() {
    require_artifacts!();
    let manifest = ArtifactManifest::load(&artifacts_dir()).unwrap();
    let net = pim_dram::workloads::nets::pimnet();
    assert_eq!(net.layers.len(), manifest.layers.len());
    for (l, m) in net.layers.iter().zip(&manifest.layers) {
        assert_eq!(l.name, m.name);
        assert_eq!(l.mac_size(), m.mac_size, "{}", l.name);
        assert_eq!(l.num_macs(), m.num_macs, "{}", l.name);
        assert_eq!(l.pool, m.pool, "{}", l.name);
    }
}
