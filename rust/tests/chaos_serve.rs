//! Chaos suite: fault-injected serving end-to-end (no artifacts, no
//! PJRT), through the versioned `api` surface and the live pool.
//!
//! Three properties are pinned here:
//!   1. **Determinism** — one seed reproduces the exact fault schedule
//!      and a bitwise-identical [`FleetReport`] (the virtual-time path).
//!   2. **Resilience** — the fleet sustains goodput through device crash
//!      and recovery: retries absorb transients, failover reroutes around
//!      a lost device, quarantine/probe reintegrates it.
//!   3. **No silent drops** — every offered request reaches exactly one
//!      terminal outcome (`accounted() == offered` in virtual time; in
//!      the live pool, shutdown drains every admitted request).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use pim_dram::api::{DevicesSpec, Job, ServeSpec, Spec};
use pim_dram::coordinator::{
    simulate_fleet, Backend, CrashSpec, FaultSpec, FleetConfig, MultiDeviceServer,
    Policy, PoolConfig, ResilienceSpec, ServeError, StormSpec, StragglerSpec,
};

/// A fully loaded fault-injected serve spec over a builtin network.
fn chaotic_spec(fault_seed: u64) -> Spec {
    let mut spec = Spec::builtin("pimnet").with_preset("conservative").with_serve(ServeSpec {
        devices: Some(DevicesSpec::Count(3)),
        batch: 4,
        policy: Policy::RoundRobin,
        faults: Some(FaultSpec {
            seed: fault_seed,
            transient: 0.1,
            straggler: Some(StragglerSpec { prob: 0.05, factor: 4.0 }),
            storm: Some(StormSpec { period: 16, duty: 2, factor: 2.0 }),
            crash: vec![CrashSpec { device: 0, after: 5, down_for: Some(10) }],
        }),
        resilience: Some(ResilienceSpec {
            retries: 2,
            quarantine_after: 2,
            probe_after_ms: 1,
            ..ResilienceSpec::default()
        }),
        load: Some(1.1),
        ..ServeSpec::default()
    });
    spec.images = 512;
    spec
}

#[test]
fn fault_injected_spec_yields_bitwise_identical_fleet_reports() {
    // Two independent Jobs from the same spec: the virtual-time replay
    // must agree to the last bit — floats included.
    let a = Job::new(chaotic_spec(0xC0FFEE)).unwrap().fleet_report().unwrap();
    let b = Job::new(chaotic_spec(0xC0FFEE)).unwrap().fleet_report().unwrap();
    assert_eq!(a, b, "same spec must reproduce the same report");
    assert_eq!(a.to_json().pretty(), b.to_json().pretty(), "canonical JSON is byte-stable");
    assert_eq!(a.p99_us.to_bits(), b.p99_us.to_bits());
    assert_eq!(a.mean_us.to_bits(), b.mean_us.to_bits());

    // The schedule actually fired, and nothing vanished.
    assert_eq!(a.offered, 512);
    assert_eq!(a.accounted(), a.offered, "every request has one terminal outcome");
    assert!(a.injected.crashes > 0, "crash window must hit: {:?}", a.injected);
    assert!(a.injected.transients > 0, "{:?}", a.injected);
    assert!(a.completed > 0 && a.goodput > 0);
}

#[test]
fn fleet_report_seed_changes_the_schedule() {
    let a = Job::new(chaotic_spec(1)).unwrap().fleet_report().unwrap();
    let b = Job::new(chaotic_spec(2)).unwrap().fleet_report().unwrap();
    // Same fleet, same load — only the fault seed differs, so the
    // degraded-mode numbers must move.
    assert_eq!(a.offered, b.offered);
    assert_ne!(a, b, "the fault seed drives the schedule");
}

#[test]
fn fleet_sustains_goodput_through_crash_and_recovery() {
    let cfg = FleetConfig {
        devices: 3,
        service_ns: 1_000_000.0, // 1 ms/image so probe windows fit the run
        batch: 4,
        requests: 1500,
        load: 1.0,
        faults: FaultSpec {
            seed: 0x5EED,
            crash: vec![CrashSpec { device: 0, after: 5, down_for: Some(10) }],
            ..FaultSpec::none()
        },
        resilience: ResilienceSpec {
            retries: 2,
            quarantine_after: 2,
            probe_after_ms: 10,
            ..ResilienceSpec::default()
        },
        ..FleetConfig::default()
    };
    let r = simulate_fleet(&cfg).unwrap();

    // No hang (we got here), no silent drop, and the fleet kept serving.
    assert_eq!(r.accounted(), r.offered);
    assert!(r.goodput > r.offered / 2, "fleet must sustain goodput: {}", r.render());
    // The crash was seen, the device was quarantined, failover rerouted
    // its traffic, and the probe reintegrated it once the window passed.
    assert!(r.injected.crashes > 0, "{}", r.render());
    assert!(r.quarantines >= 1, "{}", r.render());
    assert!(r.reintegrations >= 1, "device must come back: {}", r.render());
    assert!(r.failovers >= 1, "{}", r.render());
    assert!(r.retried >= r.failovers);
    // The recovered device worked through its crash window (probes count
    // as batch attempts) and served again afterwards.
    assert!(r.per_device_batches[0] > 15, "{:?}", r.per_device_batches);
    // Transitions pair up: down then up for device 0.
    assert!(!r.transitions.is_empty());
    assert_eq!(r.transitions[0].device, 0);
    assert!(!r.transitions[0].up);
    assert!(r.transitions.iter().any(|t| t.up && t.device == 0));
}

#[test]
fn noop_fault_section_serves_clean() {
    // `faults` present but injecting nothing: the live pool must behave
    // exactly like a spec with no fault section at all.
    let mut spec = Spec::builtin("pimnet").with_preset("conservative").with_serve(ServeSpec {
        devices: Some(DevicesSpec::Count(2)),
        batch: 4,
        faults: Some(FaultSpec::none()),
        ..ServeSpec::default()
    });
    spec.images = 8;
    let handle = Job::new(spec).unwrap().serve().unwrap();
    let elems = handle.server.image_elems();
    for i in 0..8 {
        let resp = handle.server.classify(vec![i as i32; elems]).unwrap();
        assert!(resp.class < 10);
    }
    let m = handle.server.metrics();
    assert_eq!(m.requests, 8);
    assert!(!m.degraded(), "noop faults must leave the legacy metrics shape: {}", m.report());
    handle.server.shutdown();
}

#[test]
fn live_pool_fails_over_quarantines_and_reintegrates() {
    // Device 0 is down for exactly its first batch attempt; one failure
    // quarantines it, failover reroutes to device 1, and the first probe
    // after the (1 ms) window reintegrates it.
    let spec = Spec::builtin("pimnet").with_preset("conservative").with_serve(ServeSpec {
        devices: Some(DevicesSpec::Count(2)),
        batch: 4,
        policy: Policy::RoundRobin,
        faults: Some(FaultSpec {
            seed: 3,
            crash: vec![CrashSpec { device: 0, after: 0, down_for: Some(1) }],
            ..FaultSpec::none()
        }),
        resilience: Some(ResilienceSpec {
            retries: 2,
            quarantine_after: 1,
            probe_after_ms: 1,
            ..ResilienceSpec::default()
        }),
        ..ServeSpec::default()
    });
    let handle = Job::new(spec).unwrap().serve().unwrap();
    let s = &handle.server;
    let elems = s.image_elems();

    // First request hits the crash, retries, and lands on device 1.
    let resp = s.classify(vec![1; elems]).unwrap();
    assert_eq!(resp.device, 1, "failover away from the crashed device");
    let m = s.metrics();
    assert_eq!(m.quarantines, 1);
    assert!(m.retries >= 1 && m.failovers >= 1, "{}", m.report());
    assert_eq!(s.quarantined_devices(), 1);

    // Past the probe window the round-robin cursor probes device 0; its
    // crash window is spent, so the probe succeeds and reintegrates it.
    std::thread::sleep(Duration::from_millis(5));
    for i in 0..6 {
        s.classify(vec![i + 2; elems]).unwrap();
    }
    let m = s.metrics();
    assert_eq!(m.reintegrations, 1, "{}", m.report());
    assert_eq!(s.quarantined_devices(), 0);
    assert_eq!(m.requests, 7);
    assert_eq!(m.failures, 0, "every request eventually succeeded");

    let transitions = s.health_transitions();
    assert_eq!(transitions.len(), 2, "{transitions:?}");
    assert!(!transitions[0].up && transitions[0].device == 0);
    assert!(transitions[1].up && transitions[1].device == 0);
    assert!(transitions[0].at_ns < transitions[1].at_ns);
    assert!(m.degraded());
}

#[test]
fn transient_fault_without_retries_is_typed() {
    // retries = 0 (the default): the injected fault surfaces to the
    // caller as the typed variant, not a stringly anyhow error.
    let spec = Spec::builtin("pimnet").with_preset("conservative").with_serve(ServeSpec {
        devices: Some(DevicesSpec::Count(1)),
        batch: 4,
        faults: Some(FaultSpec { seed: 9, transient: 1.0, ..FaultSpec::none() }),
        ..ServeSpec::default()
    });
    let handle = Job::new(spec).unwrap().serve().unwrap();
    let elems = handle.server.image_elems();
    let err = handle.server.classify(vec![5; elems]).unwrap_err();
    assert!(matches!(err, ServeError::Transient { device: 0 }), "{err}");
    assert!(err.to_string().contains("transient"), "{err}");
    let m = handle.server.metrics();
    assert_eq!(m.failures, 1);
    assert_eq!(m.requests, 0, "a failed request never counts as served");
    handle.server.shutdown();
}

/// A deliberately slow backend that tallies every *real* (non-padding)
/// image it executes — the witness that shutdown drains admitted work.
#[derive(Clone)]
struct SlowCounting {
    seen: Arc<AtomicU64>,
}

impl Backend for SlowCounting {
    fn batch_size(&self) -> usize {
        4
    }
    fn image_elems(&self) -> usize {
        4
    }
    fn num_classes(&self) -> usize {
        10
    }
    fn run_batch(&mut self, images: &[i32]) -> anyhow::Result<Vec<f32>> {
        // Admitted images carry a nonzero marker; padding is zeros.
        let real = images.chunks(4).filter(|c| c[0] != 0).count() as u64;
        self.seen.fetch_add(real, Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(3));
        Ok(vec![0.0; 4 * 10])
    }
}

#[test]
fn shutdown_drains_admitted_requests_without_silent_drops() {
    let seen = Arc::new(AtomicU64::new(0));
    let backend_seen = Arc::clone(&seen);
    let server = MultiDeviceServer::start(
        PoolConfig {
            devices: 1,
            batch_window: Duration::from_millis(1),
            ..PoolConfig::default()
        },
        move |_| Ok(SlowCounting { seen: Arc::clone(&backend_seen) }),
    )
    .unwrap();

    // Admit a multi-batch backlog, abandon the replies, and drop the
    // server while the worker is still mid-batch.
    let n = 10u64;
    let pendings: Vec<_> =
        (0..n).map(|i| server.submit(vec![i as i32 + 1; 4]).unwrap()).collect();
    drop(pendings);
    drop(server); // joins the worker: the drain must execute the backlog

    assert_eq!(
        seen.load(Ordering::SeqCst),
        n,
        "every admitted request must execute (or be reported shed) across shutdown"
    );
}
