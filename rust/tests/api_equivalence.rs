//! `api::Job` vs the legacy free-function path (the acceptance bar of the
//! `api` redesign): for every network × preset × shard × grid × ks point,
//! `Job::simulate_full()` on a spec must reproduce `sim::simulate()` on
//! the equivalently-built `SimConfig` **exactly** — bit-for-bit on every
//! f64 — and fail with the identical `PlanError` when the legacy path
//! fails. Plus: an inline `NetworkSpec` with custom layers runs
//! end-to-end through `report()` and `serve()`.

use pim_dram::api::{DevicesSpec, Job, ServeSpec, Spec};
use pim_dram::plan::ShardPolicy;
use pim_dram::sim::{simulate, SimConfig, SimResult};
use pim_dram::workloads::nets::all_networks;
use pim_dram::workloads::{LayerDesc, Network};

fn legacy_cfg(preset: &str, bits: usize) -> SimConfig {
    match preset {
        "conservative" => SimConfig::conservative(bits),
        "paper_favorable" => SimConfig::paper_favorable(bits),
        other => panic!("unknown preset {other}"),
    }
}

/// Bitwise comparison of everything the experiments read.
fn assert_bitwise(ctx: &str, fresh: &SimResult, job: &SimResult) {
    assert_eq!(job.net_name, fresh.net_name, "{ctx}: net_name");
    assert_eq!(job.n_bits, fresh.n_bits, "{ctx}: n_bits");
    assert_eq!(
        job.pipeline.latency_ns.to_bits(),
        fresh.pipeline.latency_ns.to_bits(),
        "{ctx}: latency"
    );
    assert_eq!(
        job.pipeline.cycle_ns.to_bits(),
        fresh.pipeline.cycle_ns.to_bits(),
        "{ctx}: cycle"
    );
    assert_eq!(job.pipeline.bottleneck, fresh.pipeline.bottleneck, "{ctx}: bottleneck");
    assert_eq!(job.total_aaps, fresh.total_aaps, "{ctx}: aaps");
    assert_eq!(
        job.total_dram_energy_nj.to_bits(),
        fresh.total_dram_energy_nj.to_bits(),
        "{ctx}: dram energy"
    );
    assert_eq!(
        job.logic_energy_nj.to_bits(),
        fresh.logic_energy_nj.to_bits(),
        "{ctx}: logic energy"
    );
    assert_eq!(
        job.throughput_ips().to_bits(),
        fresh.throughput_ips().to_bits(),
        "{ctx}: throughput"
    );
    assert_eq!(job.replicas(), fresh.replicas(), "{ctx}: replicas");
    assert_eq!(
        job.scale_out.hop_ns_total.to_bits(),
        fresh.scale_out.hop_ns_total.to_bits(),
        "{ctx}: hops"
    );
    assert_eq!(job.layers.len(), fresh.layers.len(), "{ctx}: layer count");
    for (a, b) in job.layers.iter().zip(&fresh.layers) {
        assert_eq!(a.name, b.name, "{ctx}: layer name");
        assert_eq!(a.mapping, b.mapping, "{ctx}: {} mapping", a.name);
        for (va, vb, what) in [
            (a.multiply_ns, b.multiply_ns, "multiply"),
            (a.logic_ns, b.logic_ns, "logic"),
            (a.restage_ns, b.restage_ns, "restage"),
            (a.transfer_ns, b.transfer_ns, "transfer"),
            (a.dram_energy_nj, b.dram_energy_nj, "energy"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{ctx}: {} {}", a.name, what);
        }
        assert_eq!(a.aaps, b.aaps, "{ctx}: {} aaps", a.name);
    }
}

#[test]
fn job_reproduces_simulate_across_the_design_space() {
    let grids = [(1usize, 4usize), (2, 2), (4, 4)];
    let policies = [
        ShardPolicy::Replicate,
        ShardPolicy::LayerSplit,
        ShardPolicy::Hybrid { replicas: 2 },
    ];
    let mut simulated = 0usize;
    let mut failed = 0usize;
    for net in all_networks() {
        for bits in [4usize, 8] {
            for preset in ["paper_favorable", "conservative"] {
                for (channels, ranks) in grids {
                    for policy in policies {
                        for k in [1usize, 2] {
                            let cfg = legacy_cfg(preset, bits)
                                .with_grid(channels, ranks)
                                .with_shard(policy)
                                .with_ks(vec![k]);
                            let spec = Spec::builtin(&net.name)
                                .with_preset(preset)
                                .with_precision(bits)
                                .with_grid(channels, ranks)
                                .with_shard(policy)
                                .with_ks(vec![k]);
                            let job = Job::new(spec).expect("spec resolves");
                            let ctx = format!(
                                "{} {preset} {bits}b {channels}x{ranks} {policy} k={k}",
                                net.name
                            );
                            match simulate(&net, &cfg) {
                                Err(e) => {
                                    assert_eq!(
                                        job.simulate_full().unwrap_err(),
                                        e,
                                        "{ctx}: error equality"
                                    );
                                    failed += 1;
                                }
                                Ok(fresh) => {
                                    let full = job.simulate_full().unwrap_or_else(
                                        |e| panic!("{ctx}: job failed: {e}"),
                                    );
                                    assert_bitwise(&ctx, &fresh, &full);
                                    let rep = job.report().unwrap();
                                    assert_eq!(
                                        rep.cycle_ns.to_bits(),
                                        fresh.pipeline.cycle_ns.to_bits(),
                                        "{ctx}: report cycle"
                                    );
                                    assert_eq!(
                                        rep.latency_ns.to_bits(),
                                        fresh.pipeline.latency_ns.to_bits(),
                                        "{ctx}: report latency"
                                    );
                                    assert_eq!(
                                        rep.total_aaps, fresh.total_aaps,
                                        "{ctx}: report aaps"
                                    );
                                    simulated += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    // The sweep must exercise both successful and failing lowerings.
    assert!(simulated > 0, "no point simulated");
    assert!(failed > 0, "expected some plan errors in the grid sweep");
}

#[test]
fn per_layer_ks_match_through_the_job() {
    for net in all_networks() {
        let ks: Vec<usize> =
            (0..net.layers.len()).map(|i| if i % 2 == 0 { 1 } else { 2 }).collect();
        let cfg = SimConfig::conservative(8).with_ks(ks.clone());
        let spec = Spec::builtin(&net.name)
            .with_preset("conservative")
            .with_ks(ks);
        let job = Job::new(spec).unwrap();
        let fresh = simulate(&net, &cfg).unwrap();
        let full = job.simulate_full().unwrap();
        assert_bitwise(&format!("{} per-layer ks", net.name), &fresh, &full);
    }
}

#[test]
fn toml_and_json_front_doors_agree() {
    let toml = "network = \"resnet18\"\npreset = \"conservative\"\n\
                shard = \"layersplit\"\n[dram]\nchannels = 2\n";
    let via_toml = Job::from_toml(toml).unwrap();
    let via_json =
        Job::from_json_text(&via_toml.spec().to_json_text()).unwrap();
    let a = via_toml.simulate_full().unwrap();
    let b = via_json.simulate_full().unwrap();
    assert_bitwise("toml vs json", &a, &b);
    // And both equal the legacy loader's result (now a shim over api).
    let e = pim_dram::config::load_experiment(toml).unwrap();
    let fresh = simulate(&e.network, &e.sim).unwrap();
    assert_bitwise("toml vs legacy", &fresh, &a);
}

fn tinynet() -> Network {
    Network {
        name: "tinynet".to_string(),
        layers: vec![
            LayerDesc::conv("c1", (8, 8), 1, 8, 3, 1, 1, true),
            LayerDesc::linear("fc1", 128, 32, true),
            LayerDesc::linear("fc2", 32, 10, false),
        ],
        residuals: vec![],
    }
}

#[test]
fn inline_network_runs_end_to_end() {
    let spec = Spec::inline(tinynet())
        .with_preset("conservative")
        .with_serve(ServeSpec {
            devices: Some(DevicesSpec::Count(2)),
            batch: 4,
            ..ServeSpec::default()
        });
    // The inline spec survives a JSON round-trip before running.
    let parsed = Spec::from_json_text(&spec.to_json_text()).unwrap();
    assert_eq!(parsed, spec);

    let job = Job::new(parsed).unwrap();
    let rep = job.report().unwrap();
    assert!(rep.cycle_ns > 0.0, "inline net must price");
    assert!(rep.replicas >= 1);
    assert_eq!(rep.net_name, "tinynet");

    let handle = job.serve().unwrap();
    assert_eq!(handle.devices, 2);
    let elems = handle.server.image_elems();
    assert_eq!(elems, 64, "8x8x1 input");
    for i in 0..6i32 {
        let resp = handle.server.classify(vec![i; elems]).unwrap();
        assert!(resp.class < 10);
        assert_eq!(resp.logits.len(), 10);
    }
    let m = handle.server.metrics();
    assert_eq!(m.requests, 6);
    assert_eq!(m.per_device.len(), 2);
    assert!(!m.degraded(), "fault-free serving must stay in the legacy shape");
    handle.server.shutdown();
}

#[test]
fn serve_without_faults_is_bitwise_legacy() {
    // The resilience/fault sections are strictly additive: a spec that
    // omits them (legacy) and one that spells out the noop schedule and
    // the default policy must classify bitwise-identically and report
    // clean (non-degraded) metrics.
    use pim_dram::coordinator::{FaultSpec, ResilienceSpec};

    let legacy = Spec::inline(tinynet())
        .with_preset("conservative")
        .with_serve(ServeSpec {
            devices: Some(DevicesSpec::Count(2)),
            batch: 4,
            ..ServeSpec::default()
        });
    let spelled = Spec::inline(tinynet()).with_preset("conservative").with_serve(ServeSpec {
        devices: Some(DevicesSpec::Count(2)),
        batch: 4,
        faults: Some(FaultSpec::none()),
        resilience: Some(ResilienceSpec::default()),
        ..ServeSpec::default()
    });

    // Absent sections stay absent in canonical JSON (old documents are
    // byte-stable), and both specs survive their round-trips.
    let legacy_json = legacy.to_json_text();
    assert!(!legacy_json.contains("\"faults\""), "{legacy_json}");
    assert!(!legacy_json.contains("\"resilience\""), "{legacy_json}");
    assert_eq!(Spec::from_json_text(&legacy_json).unwrap(), legacy);
    assert_eq!(Spec::from_json_text(&spelled.to_json_text()).unwrap(), spelled);

    let a = Job::new(legacy).unwrap().serve().unwrap();
    let b = Job::new(spelled).unwrap().serve().unwrap();
    let elems = a.server.image_elems();
    for i in 0..8i32 {
        let img: Vec<i32> = (0..elems).map(|e| i * 31 + e as i32).collect();
        let ra = a.server.classify(img.clone()).unwrap();
        let rb = b.server.classify(img).unwrap();
        assert_eq!(ra.class, rb.class);
        assert_eq!(ra.device, rb.device, "routing must not shift");
        for (x, y) in ra.logits.iter().zip(&rb.logits) {
            assert_eq!(x.to_bits(), y.to_bits(), "logits must match bitwise");
        }
    }
    for m in [a.server.metrics(), b.server.metrics()] {
        assert_eq!(m.requests, 8);
        assert!(!m.degraded(), "{}", m.report());
    }
    a.server.shutdown();
    b.server.shutdown();
}
