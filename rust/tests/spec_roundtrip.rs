//! The example spec corpus is canonical and the version gate holds:
//! every JSON document in `examples/specs/` parses, validates (resolves
//! through `api::Job`), and re-serializes **byte-identically**; a bumped
//! `api_version` is rejected with an error that names the problem.

use pim_dram::api::{Job, Spec};

fn specs_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/specs")
}

#[test]
fn example_specs_roundtrip_byte_identically() {
    let mut paths: Vec<_> = std::fs::read_dir(specs_dir())
        .expect("examples/specs exists")
        .map(|e| e.expect("readable dir entry").path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("json"))
        .collect();
    paths.sort();
    assert!(
        paths.len() >= 4,
        "expected at least 4 example specs, found {}",
        paths.len()
    );
    for path in paths {
        let text = std::fs::read_to_string(&path).unwrap();
        let spec = Spec::from_json_text(&text)
            .unwrap_or_else(|e| panic!("{}: parse: {e:#}", path.display()));
        // Validates and resolves without running any work.
        Job::new(spec.clone())
            .unwrap_or_else(|e| panic!("{}: validate: {e:#}", path.display()));
        assert_eq!(
            spec.to_json_text(),
            text,
            "{} is not canonical — regenerate with `pim-dram spec --print {}`",
            path.display(),
            path.display()
        );
    }
}

#[test]
fn bumped_api_version_is_rejected_with_a_clear_error() {
    let good = r#"{"api_version": 1, "network": "pimnet"}"#;
    Spec::from_json_text(good).expect("version 1 parses");

    let bumped = r#"{"api_version": 2, "network": "pimnet"}"#;
    let err = Spec::from_json_text(bumped).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("api_version"), "must name the field: {msg}");
    assert!(msg.contains('2'), "must show the offending version: {msg}");
    assert!(msg.contains('1'), "must show the supported version: {msg}");

    let missing = r#"{"network": "pimnet"}"#;
    let err = Spec::from_json_text(missing).unwrap_err();
    assert!(err.to_string().contains("api_version"), "{err}");
}

#[test]
fn serve_spec_is_optional_and_preserved() {
    // A run-only spec has no "serve" key; adding one survives the trip.
    let run_only = Spec::builtin("pimnet");
    let text = run_only.to_json_text();
    assert!(!text.contains("serve"), "run-only spec must omit serve:\n{text}");
    let spec = Spec::from_json_text(&text).unwrap();
    assert!(spec.serve.is_none());

    let served = Spec::builtin("pimnet")
        .with_serve(pim_dram::api::ServeSpec::default());
    let text = served.to_json_text();
    assert!(text.contains("serve"));
    assert_eq!(Spec::from_json_text(&text).unwrap(), served);
}
