//! The analyzer's external contract (DESIGN.md §Static analysis):
//!
//!   * every spec in `examples/specs/` checks with **zero errors**;
//!   * every case in `examples/specs/bad/` reproduces its `.diag` golden
//!     (`severity[code] location` lines) **exactly**;
//!   * diagnostic codes are unique and every emitted code is registered;
//!   * the fail-fast read path returns the identical error value the
//!     pricing path would have produced.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use pim_dram::analysis::{check_text, codes};
use pim_dram::api::{Job, Spec};

fn specs_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/specs")
}

fn json_files(dir: &Path) -> Vec<PathBuf> {
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|e| e.expect("readable dir entry").path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("json"))
        .collect();
    paths.sort();
    paths
}

#[test]
fn example_specs_check_without_errors() {
    let paths = json_files(&specs_dir());
    assert!(paths.len() >= 4, "corpus went missing");
    for path in paths {
        let text = std::fs::read_to_string(&path).unwrap();
        let d = check_text(&text);
        assert_eq!(
            d.error_count(),
            0,
            "{} must check clean:\n{}",
            path.display(),
            d.render_text()
        );
    }
}

#[test]
fn bad_corpus_matches_the_goldens_exactly() {
    let paths = json_files(&specs_dir().join("bad"));
    assert!(paths.len() >= 7, "bad corpus went missing");
    for path in paths {
        let golden = path.with_extension("diag");
        let want = std::fs::read_to_string(&golden)
            .unwrap_or_else(|e| panic!("{}: {e}", golden.display()));
        let text = std::fs::read_to_string(&path).unwrap();
        let d = check_text(&text);
        assert!(!d.is_empty(), "{} must have findings", path.display());
        assert_eq!(
            d.summary_text(),
            want,
            "{} drifted from its golden — codes/locations are a frozen \
             contract (full output:\n{})",
            path.display(),
            d.render_text()
        );
    }
}

#[test]
fn registry_codes_are_unique_and_findings_are_registered() {
    let mut seen = BTreeSet::new();
    for (code, meaning) in codes::REGISTRY {
        assert!(seen.insert(*code), "code {code} registered twice");
        assert!(!meaning.is_empty(), "{code} has no meaning");
        let (kind, num) = code.split_at(1);
        assert!(kind == "E" || kind == "W", "{code}: bad prefix");
        assert_eq!(num.len(), 3, "{code}: codes are <E|W>NNN");
        num.parse::<u32>().unwrap_or_else(|_| panic!("{code}: bad number"));
    }
    // Every code the corpus actually emits is in the registry.
    let registered: BTreeSet<_> = codes::REGISTRY.iter().map(|(c, _)| *c).collect();
    for path in json_files(&specs_dir().join("bad")) {
        let text = std::fs::read_to_string(&path).unwrap();
        for diag in check_text(&text).iter() {
            assert!(
                registered.contains(diag.code),
                "{}: {} not in codes::REGISTRY",
                path.display(),
                diag.code
            );
        }
    }
}

#[test]
fn fail_fast_error_is_the_pricing_error() {
    let text =
        std::fs::read_to_string(specs_dir().join("bad/plan_overflow.json")).unwrap();
    let d = check_text(&text);
    let carried = d.plan_error().expect("plan_overflow carries its PlanError");

    let job = Job::new(Spec::from_json_text(&text).unwrap()).unwrap();
    // The fail-fast read path returns it...
    assert_eq!(&job.report().unwrap_err(), carried);
    // ...and it is exactly what the session would have produced.
    let mut session = job.session();
    assert_eq!(&session.report(job.config()).unwrap_err(), carried);
}

#[test]
fn deny_warnings_severity_split_is_real() {
    // The serve case is all warnings: no errors, nonzero warnings — the
    // boundary `--deny-warnings` exists to promote.
    let text = std::fs::read_to_string(specs_dir().join("bad/serve_misconfigured.json"))
        .unwrap();
    let d = check_text(&text);
    assert_eq!(d.error_count(), 0, "{}", d.render_text());
    assert_eq!(d.warning_count(), 3, "{}", d.render_text());
}
