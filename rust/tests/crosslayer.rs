//! Cross-layer validation: the Rust runtime replays the first batch
//! through every per-layer PJRT artifact and must reproduce the Python
//! (jax/Pallas) activations bit-for-bit — the strongest L1↔L2↔L3
//! consistency check in the repo.

use pim_dram::runtime::{
    artifacts_available, artifacts_dir, PimNetExecutor, Runtime, Tensor,
};

fn read_i32(path: &std::path::Path) -> Vec<i32> {
    std::fs::read(path)
        .unwrap()
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn read_f32(path: &std::path::Path) -> Vec<f32> {
    std::fs::read(path)
        .unwrap()
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[test]
fn per_layer_outputs_match_python_bit_exactly() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let dir = artifacts_dir();
    if !dir.join("debug_input.bin").exists() {
        eprintln!("SKIP: debug activations not in artifacts (rebuild)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let exec = PimNetExecutor::load(&rt, &dir).unwrap();

    let input = read_i32(&dir.join("debug_input.bin"));
    let mut act = Tensor::i32(input, &exec.manifest.layers[0].in_shape);

    for (i, meta) in exec.manifest.layers.iter().enumerate() {
        act = exec.run_layer(i, act).unwrap();
        let dbg = dir.join(format!("debug_act_l{i}.bin"));
        if meta.out_dtype == "i32" {
            let want = read_i32(&dbg);
            let got = act.as_i32().unwrap();
            assert_eq!(got.len(), want.len(), "layer {i} size");
            let diffs = got.iter().zip(&want).filter(|(a, b)| a != b).count();
            assert_eq!(
                diffs, 0,
                "layer {i} ({}): {diffs}/{} elements differ from python",
                meta.name,
                want.len()
            );
        } else {
            let want = read_f32(&dbg);
            let got = act.as_f32().unwrap();
            assert_eq!(got.len(), want.len(), "layer {i} size");
            for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "layer {i} ({}) logit {j}: rust {a} vs python {b}",
                    meta.name
                );
            }
        }
    }
}
