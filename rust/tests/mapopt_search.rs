//! `pim::mapopt` search contract (DESIGN.md §Mapping optimizer):
//!
//!   * **Never worse** — across every builtin network × preset × spec k,
//!     the searched report's latency is ≤ the paper report's, and every
//!     per-layer choice is ≤ its paper stage cost (the analytic-cost
//!     property behind the branch-and-bound pruning rule).
//!   * **Deterministic** — two independent searches choose identical
//!     assignments and bitwise-identical latencies.
//!   * **Cache-friendly** — a repeated search on the same session adds
//!     zero arena misses (the sweep is absorbed by the fingerprint cache).
//!   * **API surface** — `run.mapper: "search"` routes `Job::report`
//!     through the search; the field round-trips through canonical JSON;
//!     its absence parses to the frozen paper default.

use pim_dram::api::{Job, Mapper, Spec};
use pim_dram::mapopt::{optimize, SearchKnobs};
use pim_dram::sim::{SimConfig, SimSession};
use pim_dram::workloads::nets::all_networks;

#[test]
fn search_is_never_worse_across_builtins_presets_and_ks() {
    let mut points = 0usize;
    for net in all_networks() {
        let mut session = SimSession::new(&net);
        for cfg in [
            SimConfig::conservative(8),
            SimConfig::paper_favorable(8),
            SimConfig::conservative(8).with_ks(vec![2]),
            SimConfig::conservative(4).with_ks(vec![3]),
        ] {
            let out = match optimize(&mut session, &cfg, &SearchKnobs::default()) {
                Ok(out) => out,
                Err(_) => continue, // a point the paper path cannot lower either
            };
            points += 1;
            assert!(
                out.searched.latency_ns <= out.paper.latency_ns,
                "{}: searched worse than paper",
                net.name
            );
            for c in &out.choices {
                assert!(
                    c.stage_ns <= c.paper_stage_ns,
                    "{}/{}: chosen stage worse than paper",
                    net.name,
                    c.name
                );
                assert!(c.stage_ns.is_finite() && c.stage_ns > 0.0);
            }
            assert!(out.candidates_priced >= net.layers.len());
        }
    }
    assert!(points > 0, "the sweep must exercise successful searches");
}

#[test]
fn search_strictly_improves_staging_constrained_networks() {
    for name in ["mobilenet_mini", "tinyformer"] {
        let net = all_networks().into_iter().find(|n| n.name == name).unwrap();
        let mut session = SimSession::new(&net);
        let cfg = SimConfig::conservative(8);
        let out = optimize(&mut session, &cfg, &SearchKnobs::default()).unwrap();
        assert!(
            out.improved(),
            "{name}: paper {} ns vs searched {} ns",
            out.paper.latency_ns,
            out.searched.latency_ns
        );
        assert!(out.changed_layers() > 0, "{name}: no layer changed");
        assert!(!out.fell_back, "{name}: unexpected fallback");
    }
}

#[test]
fn independent_searches_choose_identical_mappings() {
    for net in all_networks() {
        let cfg = SimConfig::conservative(8);
        let mut s1 = SimSession::new(&net);
        let mut s2 = SimSession::new(&net);
        let (a, b) = (
            optimize(&mut s1, &cfg, &SearchKnobs::default()),
            optimize(&mut s2, &cfg, &SearchKnobs::default()),
        );
        let (Ok(a), Ok(b)) = (a, b) else { continue };
        assert_eq!(a.assignment(), b.assignment(), "{}", net.name);
        assert_eq!(
            a.searched.latency_ns.to_bits(),
            b.searched.latency_ns.to_bits(),
            "{}",
            net.name
        );
        assert_eq!(a.candidates_priced, b.candidates_priced, "{}", net.name);
        assert_eq!(a.pruned_branches, b.pruned_branches, "{}", net.name);
    }
}

#[test]
fn repeated_search_is_fully_cached() {
    let net = all_networks().into_iter().find(|n| n.name == "vgg16").unwrap();
    let mut session = SimSession::new(&net);
    let cfg = SimConfig::conservative(8);
    let first = optimize(&mut session, &cfg, &SearchKnobs::default()).unwrap();
    let (_, misses_first) = session.cache_stats();
    let second = optimize(&mut session, &cfg, &SearchKnobs::default()).unwrap();
    let (_, misses_second) = session.cache_stats();
    assert_eq!(misses_first, misses_second, "second search must be all hits");
    assert_eq!(first.assignment(), second.assignment());
    assert_eq!(
        first.searched.latency_ns.to_bits(),
        second.searched.latency_ns.to_bits()
    );
}

#[test]
fn job_report_routes_through_the_search_mapper() {
    let spec = Spec::builtin("mobilenet_mini")
        .with_preset("conservative")
        .with_mapper(Mapper::Search);
    let job = Job::new(spec.clone()).unwrap();
    let report = job.report().unwrap();
    let out = job.search().unwrap();
    assert_eq!(report.latency_ns.to_bits(), out.searched.latency_ns.to_bits());
    // The searched report strictly beats the same spec under the paper
    // mapper.
    let paper = Job::new(spec.with_mapper(Mapper::Paper)).unwrap().report().unwrap();
    assert!(report.latency_ns < paper.latency_ns);
    assert_eq!(paper.latency_ns.to_bits(), out.paper.latency_ns.to_bits());
}

#[test]
fn mapper_field_round_trips_and_defaults_to_paper() {
    // Absent → the frozen default.
    let spec = Spec::builtin("pimnet");
    assert_eq!(spec.run.mapper, Mapper::Paper);
    let text = spec.to_json_text();
    assert!(!text.contains("mapper"), "default mapper must not be emitted");
    assert_eq!(Spec::from_json_text(&text).unwrap().run.mapper, Mapper::Paper);

    // Present → round-trips through the canonical form (fixed point).
    let mut spec = Spec::builtin("tinyformer")
        .with_preset("conservative")
        .with_mapper(Mapper::Search);
    spec.run.beam = 2;
    spec.run.search_budget = 16;
    let text = spec.to_json_text();
    assert!(text.contains("\"mapper\": \"search\""), "{text}");
    let reparsed = Spec::from_json_text(&text).unwrap();
    assert_eq!(reparsed.run.mapper, Mapper::Search);
    assert_eq!(reparsed.run.beam, 2);
    assert_eq!(reparsed.run.search_budget, 16);
    assert_eq!(reparsed.to_json_text(), text, "canonical form must be a fixed point");

    // Unknown spelling is a schema error.
    let bad = text.replace("\"search\"", "\"exhaustive\"");
    assert!(Spec::from_json_text(&bad).is_err());
}

#[test]
fn search_knob_warnings_surface_through_check() {
    use pim_dram::analysis::{check_spec, codes};
    let mut spec = Spec::builtin("pimnet")
        .with_preset("conservative")
        .with_mapper(Mapper::Search);
    spec.run.search_budget = 0;
    spec.run.beam = 0;
    let d = check_spec(&spec);
    assert_eq!(d.error_count(), 0, "{}", d.render_text());
    for code in [codes::W_SEARCH_BUDGET_ZERO, codes::W_BEAM_CLAMPED] {
        assert!(d.iter().any(|f| f.code == code), "{code}:\n{}", d.render_text());
    }
    // The same spec under the paper mapper has no W05x findings.
    let d = check_spec(&Spec::builtin("pimnet").with_preset("conservative"));
    assert!(
        d.iter().all(|f| !f.code.starts_with("W05")),
        "{}",
        d.render_text()
    );
}
