//! Coordinator end-to-end: server startup, batched classification,
//! metrics, graceful shutdown. Skips when artifacts are missing.

use std::time::Duration;

use pim_dram::coordinator::{InferenceServer, ServerConfig};
use pim_dram::runtime::{
    artifacts_available, artifacts_dir, ArtifactManifest, DigitsDataset,
};

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn load_dataset() -> DigitsDataset {
    let dir = artifacts_dir();
    let m = ArtifactManifest::load(&dir).unwrap();
    DigitsDataset::load(&dir, &m).unwrap()
}

#[test]
fn serve_classifies_with_reasonable_accuracy() {
    require_artifacts!();
    let ds = load_dataset();
    let server = InferenceServer::start(ServerConfig::default()).unwrap();
    let n = ds.count.min(24);
    let mut correct = 0;
    for i in 0..n {
        let (img, lbl) = ds.batch(i, 1);
        let resp = server.classify(img).unwrap();
        assert!(resp.logits.len() == 10);
        assert!(resp.latency > Duration::ZERO);
        correct += (resp.class == lbl[0] as usize) as usize;
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.6, "accuracy {acc}");

    let m = server.metrics();
    assert_eq!(m.requests, n as u64);
    assert!(m.batches >= 1);
    assert!(m.latency_mean_us > 0.0);
    server.shutdown();
}

#[test]
fn serve_batches_concurrent_clients() {
    require_artifacts!();
    let ds = load_dataset();
    let server = std::sync::Arc::new(
        InferenceServer::start(ServerConfig {
            batch_window: Duration::from_millis(20),
            ..ServerConfig::default()
        })
        .unwrap(),
    );
    let batch = server.batch_size();

    // Submit a full batch concurrently: the batcher should coalesce them
    // into few executions (padding makes the count exact only when the
    // window aligns, so assert an upper bound).
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..batch {
            let server = std::sync::Arc::clone(&server);
            let (img, _) = ds.batch(i, 1);
            handles.push(scope.spawn(move || server.classify(img).unwrap()));
        }
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.class < 10);
        }
    });
    let m = server.metrics();
    assert_eq!(m.requests, batch as u64);
    assert!(
        m.batches <= batch as u64,
        "no batching happened: {} batches",
        m.batches
    );
}

#[test]
fn serve_rejects_wrong_image_size() {
    require_artifacts!();
    let server = InferenceServer::start(ServerConfig::default()).unwrap();
    assert!(server.classify(vec![0; 3]).is_err());
    server.shutdown();
}

#[test]
fn server_startup_fails_cleanly_without_artifacts() {
    let cfg = ServerConfig {
        artifacts: std::path::PathBuf::from("/nonexistent/artifacts"),
        ..ServerConfig::default()
    };
    assert!(InferenceServer::start(cfg).is_err());
}
