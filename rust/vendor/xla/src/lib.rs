//! Offline stub of the `xla` crate's PJRT surface.
//!
//! The real crate links the PJRT C API and an XLA build, neither of which
//! exists in this environment. This stub provides the exact API slice
//! `pim_dram::runtime` consumes so `cargo build --features pjrt` and
//! `cargo clippy --all-features` type-check; every runtime entry point
//! returns [`Error::Unavailable`]. Deployments with the real toolchain
//! replace the `xla` path dependency in `rust/Cargo.toml` — the consuming
//! code needs no edits, and the artifact-gated integration tests go live.

use std::fmt;
use std::path::Path;

/// Stub error: either "this build has no PJRT" or a typed message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    Unavailable,
    Msg(String),
}

impl Error {
    fn unavailable<T>() -> Result<T> {
        Err(Error::Unavailable)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable => write!(
                f,
                "PJRT is not available in this offline build (the `xla` \
                 dependency is a stub; link the real crate to execute \
                 artifacts)"
            ),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Element types the artifact layer distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// Marker trait for host scalar types crossing the literal boundary.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
}

/// Host-side literal (stub: retains only the logical shape/type).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
}

/// Array shape view returned by [`Literal::array_shape`].
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { ty: T::TY, dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        Ok(Literal { ty: self.ty, dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { ty: self.ty, dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Error::unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Error::unavailable()
    }
}

/// Parsed HLO module (stub: opaque).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Error::unavailable()
    }
}

/// XLA computation handle (stub: opaque).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle returned by execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Error::unavailable()
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Error::unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Error::unavailable()
    }
}

/// Compiled executable (stub: execution always fails).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Error::unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_explicit() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(lit.array_shape().unwrap().dims(), &[3]);
        assert!(lit.to_vec::<i32>().is_err());
        let msg = Error::Unavailable.to_string();
        assert!(msg.contains("offline"));
    }
}
