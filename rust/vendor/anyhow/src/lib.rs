//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! This build environment has no crates.io access, so the workspace vendors
//! the small slice of `anyhow` the codebase uses as a path dependency:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Swapping back to the real crate
//! is a one-line change in `rust/Cargo.toml`; no source edits are needed.
//!
//! Semantics mirror upstream where it matters to callers:
//!   * `Display` prints the outermost message only.
//!   * `{:#}` (alternate) prints the whole chain joined by `": "`.
//!   * `Debug` prints the message plus a `Caused by:` list (what
//!     `unwrap()` / `fn main() -> anyhow::Result<()>` show).
//!   * Any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically-typed error with a chain of context messages.
pub struct Error {
    /// Context frames, outermost (most recently attached) first.
    frames: Vec<String>,
    /// The originating typed error, if any.
    root: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()], root: None }
    }

    /// Wrap a typed error (what `?` conversion uses).
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error { frames: Vec::new(), root: Some(Box::new(error)) }
    }

    /// Attach an outer context message (also available through the
    /// [`Context`] trait on `Result`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost first (contexts, then the root).
    fn chain_messages(&self) -> Vec<String> {
        let mut msgs = self.frames.clone();
        if let Some(root) = &self.root {
            msgs.push(root.to_string());
        }
        msgs
    }

    /// Reference to the root typed error, if this error wraps one.
    pub fn root_cause(&self) -> Option<&(dyn StdError + Send + Sync + 'static)> {
        self.root.as_deref()
    }

    /// Attempt to downcast the root error to a concrete type.
    pub fn downcast_ref<E: StdError + Send + Sync + 'static>(&self) -> Option<&E> {
        self.root.as_deref().and_then(|e| e.downcast_ref::<E>())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msgs = self.chain_messages();
        if f.alternate() {
            write!(f, "{}", msgs.join(": "))
        } else {
            write!(f, "{}", msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msgs = self.chain_messages();
        write!(f, "{}", msgs.first().map(String::as_str).unwrap_or(""))?;
        if msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option` (upstream spells this `Context<T, E>`; the extra parameter
/// is not needed for method-call resolution).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("key absent").unwrap_err();
        assert_eq!(e.to_string(), "key absent");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_messages() {
        fn f(x: usize) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through with 1");
        let from_string = anyhow!(String::from("owned message"));
        assert_eq!(from_string.to_string(), "owned message");
    }

    #[test]
    fn downcast_reaches_root() {
        let e = Error::new(io_err()).context("outer");
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        assert!(e.root_cause().is_some());
    }
}
