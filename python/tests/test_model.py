"""L2 model tests: float training, quantization, quantized PIM graph."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.datasets import IMG, NUM_CLASSES, make_digits
from compile.kernels.ref import conv2d_int_ref, im2col


@pytest.fixture(scope="module")
def tiny_data():
    return make_digits(256, seed=11)


@pytest.fixture(scope="module")
def trained(tiny_data):
    images, labels = tiny_data
    params = M.init_params(jax.random.PRNGKey(0))
    params, log = M.train(params, images, labels, steps=80, batch=64)
    return params, log


@pytest.fixture(scope="module")
def quantized(trained, tiny_data):
    params, _ = trained
    images, _ = tiny_data
    return M.quantize_model(params, images[:128], wa=8, ww=8)


class TestDataset:
    def test_shapes_and_range(self, tiny_data):
        images, labels = tiny_data
        assert images.shape == (256, IMG, IMG, 1)
        assert images.min() >= 0.0 and images.max() <= 1.0
        assert labels.min() >= 0 and labels.max() < NUM_CLASSES

    def test_balanced_classes(self, tiny_data):
        _, labels = tiny_data
        counts = np.bincount(labels, minlength=NUM_CLASSES)
        assert counts.min() >= 20  # 256/10 ± shuffle

    def test_deterministic(self):
        a, la = make_digits(16, seed=5)
        b, lb = make_digits(16, seed=5)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(la, lb)

    def test_seed_changes_data(self):
        a, _ = make_digits(16, seed=5)
        b, _ = make_digits(16, seed=6)
        assert not np.array_equal(a, b)


class TestFloatModel:
    def test_forward_shape(self):
        params = M.init_params(jax.random.PRNGKey(1))
        x = jnp.zeros((4, IMG, IMG, 1), jnp.float32)
        assert M.apply_float(params, x).shape == (4, NUM_CLASSES)

    def test_layer_defs_chain(self):
        """Each layer's out_shape must equal the next layer's in_shape
        (modulo the conv→fc flatten)."""
        for prev, nxt in zip(M.LAYER_DEFS, M.LAYER_DEFS[1:]):
            prev_elems = int(np.prod(prev.out_shape))
            nxt_elems = int(np.prod(nxt.in_shape))
            assert prev_elems == nxt_elems, (prev.name, nxt.name)

    def test_training_reduces_loss(self, trained):
        _, log = trained
        assert log[-1] < log[0] * 0.5

    def test_trained_accuracy(self, trained, tiny_data):
        params, _ = trained
        images, labels = tiny_data
        acc = M.accuracy(M.apply_float(params, jnp.asarray(images[:128])),
                         labels[:128])
        assert acc > 0.8


class TestIm2col:
    def test_conv_equals_lax(self):
        """im2col+matmul conv must equal lax.conv on random ints."""
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(0, 16, size=(2, 8, 8, 3)), jnp.int32)
        w = jnp.asarray(rng.integers(-8, 8, size=(3, 3, 3, 5)), jnp.int32)
        got = conv2d_int_ref(x, w, stride=1, pad=1)
        want = jax.lax.conv_general_dilated(
            x.astype(jnp.float32), w.astype(jnp.float32),
            (1, 1), [(1, 1), (1, 1)], dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_stride_two(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.integers(0, 4, size=(1, 6, 6, 2)), jnp.int32)
        w = jnp.asarray(rng.integers(-2, 2, size=(2, 2, 2, 3)), jnp.int32)
        got = conv2d_int_ref(x, w, stride=2, pad=0)
        assert got.shape == (1, 3, 3, 3)

    def test_geometry(self):
        x = jnp.zeros((2, 16, 16, 1), jnp.int32)
        cols, (b, oh, ow) = im2col(x, 3, 3, 1, 1)
        assert (b, oh, ow) == (2, 16, 16)
        assert cols.shape == (2 * 16 * 16, 9)


class TestQuantModel:
    def test_scales_positive(self, quantized):
        for lq in quantized.layers:
            assert lq.w_scale > 0 and lq.in_scale > 0

    def test_weight_range(self, quantized):
        for lq in quantized.layers:
            assert lq.weights_q.max() < 2 ** (quantized.ww - 1)
            assert lq.weights_q.min() >= -(2 ** (quantized.ww - 1))

    def test_final_layer_dequantizes(self, quantized):
        assert quantized.layers[-1].out_scale == 0.0
        with pytest.raises(ValueError):
            _ = quantized.layers[-1].requant_scale

    def test_quant_input_range(self, quantized, tiny_data):
        images, _ = tiny_data
        xq = M.quantize_input(images[:8], quantized)
        assert int(xq.min()) >= 0
        assert int(xq.max()) <= 2**quantized.wa - 1

    def test_full_equals_layer_composition(self, quantized, tiny_data):
        """apply_quant == folding quant_layer_apply — the property that lets
        the Rust pipeline execute per-bank artifacts independently."""
        images, _ = tiny_data
        x = M.quantize_input(images[:4], quantized)
        full = np.asarray(M.apply_quant(quantized, x))
        y = x
        for lq in quantized.layers:
            y = M.quant_layer_apply(lq, quantized, y)
        np.testing.assert_array_equal(full, np.asarray(y))

    def test_quant_matches_float_argmax(self, quantized, trained, tiny_data):
        params, _ = trained
        images, labels = tiny_data
        x = M.quantize_input(images[:16], quantized)
        logits_q = np.asarray(M.apply_quant(quantized, x))
        logits_f = np.asarray(M.apply_float(params, jnp.asarray(images[:16])))
        agree = (logits_q.argmax(1) == logits_f.argmax(1)).mean()
        assert agree >= 0.85

    def test_intermediate_dtypes(self, quantized, tiny_data):
        images, _ = tiny_data
        x = M.quantize_input(images[:2], quantized)
        for lq in quantized.layers[:-1]:
            x = M.quant_layer_apply(lq, quantized, x)
            assert x.dtype == jnp.int32
            assert int(x.min()) >= 0
            assert int(x.max()) <= 2**quantized.wa - 1
        logits = M.quant_layer_apply(quantized.layers[-1], quantized, x)
        assert logits.dtype == jnp.float32
