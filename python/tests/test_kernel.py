"""L1 correctness: bit-serial Pallas matmul vs the pure-jnp oracle.

This is the core numeric signal of the reproduction: the kernel implements
the paper's AND + shift-add decomposition (§III-B) and must be *bit-exact*
against integer matmul for all in-range operands.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitserial_matmul, bits_required, max_abs_acc
from compile.kernels.ref import matmul_ref


def _rand_operands(rng, m, k, n, wa, ww):
    x = rng.integers(0, 2**wa, size=(m, k), dtype=np.int64).astype(np.int32)
    w = rng.integers(-(2 ** (ww - 1)), 2 ** (ww - 1), size=(k, n),
                     dtype=np.int64).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(w)


def _assert_exact(x, w, wa, ww, **kw):
    got = np.asarray(bitserial_matmul(x, w, wa=wa, ww=ww, **kw))
    want = np.asarray(matmul_ref(x, w))
    np.testing.assert_array_equal(got, want)


class TestFixedCases:
    def test_identity(self):
        x = jnp.eye(4, dtype=jnp.int32) * 3
        w = jnp.arange(16, dtype=jnp.int32).reshape(4, 4) - 8
        _assert_exact(x, w, 2, 5)

    def test_all_zero(self):
        x = jnp.zeros((3, 4), jnp.int32)
        w = jnp.zeros((4, 2), jnp.int32)
        _assert_exact(x, w, 8, 8)

    def test_max_magnitude(self):
        """Extremes of both ranges: a=2^wa-1, w=-2^(ww-1) (MSB plane)."""
        wa, ww = 8, 8
        x = jnp.full((2, 8), 2**wa - 1, jnp.int32)
        w = jnp.full((8, 2), -(2 ** (ww - 1)), jnp.int32)
        _assert_exact(x, w, wa, ww)

    def test_max_positive_weights(self):
        wa, ww = 8, 8
        x = jnp.full((2, 8), 2**wa - 1, jnp.int32)
        w = jnp.full((8, 2), 2 ** (ww - 1) - 1, jnp.int32)
        _assert_exact(x, w, wa, ww)

    def test_single_bit_operands(self):
        """wa=ww=1: weights are two's-complement 1-bit, i.e. {-1, 0}."""
        x = jnp.array([[1, 0, 1]], jnp.int32)
        w = jnp.array([[-1], [0], [-1]], jnp.int32)
        _assert_exact(x, w, 1, 1)

    def test_asymmetric_widths(self):
        rng = np.random.default_rng(3)
        x, w = _rand_operands(rng, 4, 7, 3, 2, 11)
        _assert_exact(x, w, 2, 11)

    def test_vector_times_matrix(self):
        """M=1 — the paper's MVM case."""
        rng = np.random.default_rng(4)
        x, w = _rand_operands(rng, 1, 64, 16, 8, 8)
        _assert_exact(x, w, 8, 8)


class TestBlocking:
    """Output tiling must not change results (BlockSpec schedule only)."""

    @pytest.mark.parametrize("bm,bn", [(2, 4), (4, 2), (1, 1), (4, 8)])
    def test_blocked_equals_unblocked(self, bm, bn):
        rng = np.random.default_rng(5)
        x, w = _rand_operands(rng, 4, 6, 8, 6, 6)
        got = np.asarray(
            bitserial_matmul(x, w, wa=6, ww=6, block_m=bm, block_n=bn)
        )
        np.testing.assert_array_equal(got, np.asarray(matmul_ref(x, w)))

    def test_indivisible_block_raises(self):
        x = jnp.zeros((4, 4), jnp.int32)
        w = jnp.zeros((4, 4), jnp.int32)
        with pytest.raises(ValueError, match="not divisible"):
            bitserial_matmul(x, w, wa=4, ww=4, block_m=3)


class TestValidation:
    def test_contraction_mismatch(self):
        with pytest.raises(ValueError, match="contraction"):
            bitserial_matmul(jnp.zeros((2, 3), jnp.int32),
                             jnp.zeros((4, 2), jnp.int32))

    def test_overflow_guard(self):
        x = jnp.zeros((1, 2**16), jnp.int32)
        w = jnp.zeros((2**16, 1), jnp.int32)
        with pytest.raises(ValueError, match="overflow"):
            bitserial_matmul(x, w, wa=15, ww=15)

    def test_bitwidth_guard(self):
        x = jnp.zeros((1, 1), jnp.int32)
        with pytest.raises(ValueError, match="bit widths"):
            bitserial_matmul(x, x, wa=0, ww=8)

    def test_bits_required_monotone(self):
        prev = 0
        for k in [1, 4, 64, 4096]:
            b = bits_required(k, 8, 8)
            assert b >= prev
            prev = b
        # K-deep 8x8 MAC: product fits 16 bits; 4096-deep adds 12 bits.
        assert bits_required(4096, 8, 8) <= 16 + 12 + 1

    def test_max_abs_acc(self):
        assert max_abs_acc(1, 8, 8) == 255 * 128
        assert max_abs_acc(10, 1, 1) == 10


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 5),
    k=st.integers(1, 7),
    n=st.integers(1, 5),
    wa=st.integers(1, 9),
    ww=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_exactness(m, k, n, wa, ww, seed):
    """Property: kernel == integer matmul for every in-range operand set."""
    rng = np.random.default_rng(seed)
    x, w = _rand_operands(rng, m, k, n, wa, ww)
    _assert_exact(x, w, wa, ww)


@settings(max_examples=10, deadline=None)
@given(
    wa=st.integers(1, 8),
    ww=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_bit_boundaries(wa, ww, seed):
    """Operands drawn only from range boundaries (overflow corners)."""
    rng = np.random.default_rng(seed)
    xs = np.array([0, 2**wa - 1], dtype=np.int32)
    wsv = np.array([-(2 ** (ww - 1)), 0, 2 ** (ww - 1) - 1], dtype=np.int32)
    x = jnp.asarray(rng.choice(xs, size=(3, 4)))
    w = jnp.asarray(rng.choice(wsv, size=(4, 3)))
    _assert_exact(x, w, wa, ww)
