"""SFU kernels (ReLU → BN → quantize chain, maxpool) vs jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_sfu, maxpool2x2, quantize_fixedpoint_params
from compile.kernels.ref import fused_sfu_ref, maxpool2x2_ref


class TestQuantizeParams:
    def test_roundtrip_precision(self):
        for scale in [1.0, 0.5, 0.01, 3.7e-4]:
            mult, shift = quantize_fixedpoint_params(scale)
            assert abs(mult / (1 << shift) - scale) < 2 ** -(shift - 1)

    def test_zero_scale(self):
        mult, _ = quantize_fixedpoint_params(0.0)
        assert mult == 0

    def test_negative_scale_rejected(self):
        with pytest.raises(ValueError):
            quantize_fixedpoint_params(-1.0)

    def test_huge_scale_rejected(self):
        with pytest.raises(ValueError):
            quantize_fixedpoint_params(1e6)


class TestFusedSfu:
    def _check(self, acc, bias, scale, bits, relu):
        got = np.asarray(fused_sfu(acc, bias, scale=scale, bits=bits, relu=relu))
        mult, shift = quantize_fixedpoint_params(scale)
        want = np.asarray(
            fused_sfu_ref(acc, bias, mult=mult, shift=shift, bits=bits, relu=relu)
        )
        np.testing.assert_array_equal(got, want)

    def test_relu_zeroes_negative(self):
        acc = jnp.array([[-100, 0, 100]], jnp.int32)
        bias = jnp.zeros((3,), jnp.int32)
        out = np.asarray(fused_sfu(acc, bias, scale=1.0, bits=8, relu=True))
        assert out[0, 0] == 0 and out[0, 1] == 0 and out[0, 2] == 100

    def test_clamp_to_bits(self):
        acc = jnp.array([[10_000]], jnp.int32)
        bias = jnp.zeros((1,), jnp.int32)
        out = np.asarray(fused_sfu(acc, bias, scale=1.0, bits=8, relu=True))
        assert out[0, 0] == 255

    def test_no_relu_signed_range(self):
        acc = jnp.array([[-10_000, 10_000]], jnp.int32)
        bias = jnp.zeros((2,), jnp.int32)
        out = np.asarray(fused_sfu(acc, bias, scale=1.0, bits=8, relu=False))
        assert out[0, 0] == -128 and out[0, 1] == 255

    def test_bias_applied_before_relu(self):
        acc = jnp.array([[-5]], jnp.int32)
        bias = jnp.array([10], jnp.int32)
        out = np.asarray(fused_sfu(acc, bias, scale=1.0, bits=8, relu=True))
        assert out[0, 0] == 5

    def test_bias_shape_guard(self):
        with pytest.raises(ValueError, match="bias shape"):
            fused_sfu(jnp.zeros((2, 3), jnp.int32), jnp.zeros((2,), jnp.int32),
                      scale=1.0)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 5),
        n=st.integers(1, 6),
        bits=st.integers(2, 10),
        relu=st.booleans(),
        scale=st.floats(1e-5, 2.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, m, n, bits, relu, scale, seed):
        rng = np.random.default_rng(seed)
        acc = jnp.asarray(rng.integers(-(2**20), 2**20, size=(m, n)), jnp.int32)
        bias = jnp.asarray(rng.integers(-(2**10), 2**10, size=(n,)), jnp.int32)
        self._check(acc, bias, scale, bits, relu)


class TestMaxpool:
    def test_simple(self):
        x = jnp.arange(16, dtype=jnp.int32).reshape(1, 4, 4, 1)
        out = np.asarray(maxpool2x2(x))
        np.testing.assert_array_equal(
            out[0, :, :, 0], np.array([[5, 7], [13, 15]])
        )

    def test_odd_size_rejected(self):
        with pytest.raises(ValueError, match="even"):
            maxpool2x2(jnp.zeros((1, 3, 4, 1), jnp.int32))

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.sampled_from([2, 4, 8]),
        w=st.sampled_from([2, 4, 6]),
        c=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, b, h, w, c, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(-1000, 1000, size=(b, h, w, c)), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(maxpool2x2(x)), np.asarray(maxpool2x2_ref(x))
        )
