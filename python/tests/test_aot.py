"""AOT lowering tests: HLO text interchange + manifest/test-vector sanity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels import bitserial_matmul

ARTIFACTS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "artifacts",
)


class TestLowering:
    def test_hlo_text_header(self):
        hlo = aot.lower_to_hlo_text(
            lambda x: (x + 1,), jax.ShapeDtypeStruct((2, 2), jnp.int32)
        )
        assert hlo.startswith("HloModule")

    def test_hlo_text_tuple_root(self):
        """return_tuple=True: the root must be a tuple (rust uses to_tuple1)."""
        hlo = aot.lower_to_hlo_text(
            lambda x: (x * 2,), jax.ShapeDtypeStruct((3,), jnp.float32)
        )
        assert "tuple" in hlo

    def test_pallas_kernel_lowers(self):
        """The bit-serial kernel must lower to plain HLO (interpret mode)."""
        hlo = aot.lower_to_hlo_text(
            lambda x, w: (bitserial_matmul(x, w, wa=4, ww=4),),
            jax.ShapeDtypeStruct((2, 4), jnp.int32),
            jax.ShapeDtypeStruct((4, 2), jnp.int32),
        )
        assert hlo.startswith("HloModule")
        assert "custom-call" not in hlo.lower(), (
            "interpret=True must not emit Mosaic custom-calls"
        )

    def test_deterministic_lowering(self):
        f = lambda x: (x - 3,)
        spec = jax.ShapeDtypeStruct((2,), jnp.int32)
        assert aot.lower_to_hlo_text(f, spec) == aot.lower_to_hlo_text(f, spec)

    def test_large_baked_constants_not_elided(self):
        """Regression: the default HLO printer elides big literals as
        `constant({...})`, silently corrupting baked weights on the Rust
        side (EXPERIMENTS.md §Debugging). Every weight value must survive
        into the text."""
        w = jnp.asarray(np.arange(1024, dtype=np.int32).reshape(32, 32))
        hlo = aot.lower_to_hlo_text(
            lambda x: (x @ w,), jax.ShapeDtypeStruct((2, 32), jnp.int32)
        )
        assert "{...}" not in hlo
        # Spot-check some payload values actually present.
        assert "1023" in hlo and "517" in hlo


class TestTestVectors:
    def test_vectors_internally_consistent(self):
        tv = aot._test_vectors()
        assert len(tv["matmul_cases"]) >= 5
        for case in tv["matmul_cases"]:
            x = np.array(case["x"]).reshape(case["m"], case["k"])
            w = np.array(case["w"]).reshape(case["k"], case["n"])
            y = np.array(case["y"]).reshape(case["m"], case["n"])
            np.testing.assert_array_equal(x @ w, y)
            assert x.min() >= 0 and x.max() < 2 ** case["wa"]
            assert w.min() >= -(2 ** (case["ww"] - 1))
            assert w.max() < 2 ** (case["ww"] - 1)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    """Validate whatever `make artifacts` actually produced."""

    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_layer_chain_shapes(self, manifest):
        layers = manifest["layers"]
        for prev, nxt in zip(layers, layers[1:]):
            assert int(np.prod(prev["out_shape"])) == int(np.prod(nxt["in_shape"]))

    def test_files_exist(self, manifest):
        for l in manifest["layers"]:
            assert os.path.exists(os.path.join(ARTIFACTS, l["file"]))
        assert os.path.exists(os.path.join(ARTIFACTS, manifest["model_hlo"]))
        assert os.path.exists(os.path.join(ARTIFACTS, manifest["mvm_hlo"]))

    def test_dataset_sizes(self, manifest):
        ti = manifest["test_images"]
        img_bytes = os.path.getsize(os.path.join(ARTIFACTS, ti["file"]))
        assert img_bytes == ti["count"] * int(np.prod(ti["shape"])) * 4
        lbl_bytes = os.path.getsize(
            os.path.join(ARTIFACTS, manifest["test_labels"]["file"])
        )
        assert lbl_bytes == manifest["test_labels"]["count"]

    def test_quant_accuracy_recorded(self, manifest):
        assert manifest["quant_test_accuracy"] > 0.5

    def test_mac_geometry_matches_known_shapes(self, manifest):
        by_name = {l["name"]: l for l in manifest["layers"]}
        assert by_name["conv1"]["mac_size"] == 9
        assert by_name["conv2"]["mac_size"] == 144
        assert by_name["fc1"]["mac_size"] == 512
        assert by_name["fc2"]["mac_size"] == 128
        assert by_name["fc1"]["num_macs"] == 128
