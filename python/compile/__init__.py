"""Build-time compile path (L1 kernels + L2 model + AOT lowering).

Nothing in this package is imported at runtime; `make artifacts` runs it once
and the Rust coordinator consumes `artifacts/` from then on.
"""

import jax

# The SFU quantize unit models its internal datapath with 64-bit integers
# (acc × fixed-point multiplier). jax silently truncates i64 → i32 unless
# x64 is enabled, which would corrupt the requantization — enable globally
# for the whole build path. All float tensors pin dtype explicitly.
jax.config.update("jax_enable_x64", True)

