"""L1 Pallas kernels (bit-serial matmul, SFU chain) and their jnp oracles."""

from . import ref  # noqa: F401
from .bitserial_matmul import bitserial_matmul, bits_required, max_abs_acc  # noqa: F401
from .sfu import fused_sfu, maxpool2x2, quantize_fixedpoint_params  # noqa: F401
