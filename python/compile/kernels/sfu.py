"""L1 Pallas kernels for the PIM-DRAM Special Function Units (§IV-A.3–5).

Each DRAM bank's peripheral pipeline is accumulator → ReLU → BatchNorm →
Quantize → (MaxPool) → Transpose. For inference the BatchNorm parameters are
constants (§IV-A.4), so ReLU + BN + Quantize fold into a single fixed-point
affine requantization, which is what the fused kernel below computes:

    y = clamp( (max(acc + bias, 0) * mult + round) >> shift, 0, 2**bits - 1 )

``mult``/``shift`` encode the float scale ``s = s_a * s_w / s_out`` (and the
BN scale) as a fixed-point multiplier, exactly like the hardware's shift-add
quantize unit. The MaxPool kernel implements the §IV-A.5 running-max unit
over 2×2 windows.

All kernels run ``interpret=True`` (CPU PJRT; see aot_recipe).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_sfu", "maxpool2x2", "quantize_fixedpoint_params"]

#: Fixed-point fraction bits used by the quantize unit's multiplier.
_FIXED_SHIFT = 16


def quantize_fixedpoint_params(scale: float, shift: int = _FIXED_SHIFT):
    """Encode a float requant scale as (mult, shift) for the quantize unit.

    ``y ≈ (x * mult) >> shift`` with rounding; mult is a non-negative int32.
    """
    if scale < 0:
        raise ValueError(f"requant scale must be >= 0, got {scale}")
    mult = int(round(scale * (1 << shift)))
    if mult >= 2**31:
        raise ValueError(f"scale {scale} too large for fixed-point encoding")
    return mult, shift


def _fused_sfu_kernel(acc_ref, bias_ref, o_ref, *, mult, shift, bits, relu):
    """ReLU → (folded BN) → fixed-point quantize, one output block."""
    acc = acc_ref[...] + bias_ref[...]
    if relu:
        acc = jnp.maximum(acc, 0)
    # Quantize unit: widen to i64 for the fixed-point product, round to
    # nearest, arithmetic shift back down. (The hardware uses a shifter and
    # an adder; i64 here only to model the wider internal datapath.)
    prod = acc.astype(jnp.int64) * jnp.int64(mult)
    rounded = (prod + jnp.int64(1 << (shift - 1))) >> shift
    hi = jnp.int64((1 << bits) - 1)
    lo = jnp.int64(0) if relu else jnp.int64(-(1 << (bits - 1)))
    o_ref[...] = jnp.clip(rounded, lo, hi).astype(jnp.int32)


def fused_sfu(acc, bias, *, scale: float, bits: int = 8, relu: bool = True,
              interpret: bool = True):
    """Apply the bank SFU chain to an accumulator tensor.

    Args:
      acc: ``[M, N]`` int32 MAC accumulator outputs (adder tree results).
      bias: ``[N]`` int32 per-output-channel bias in accumulator scale
        (conv bias + BN shift folded).
      scale: float requantization scale (s_a*s_w*bn_gamma / s_out).
      bits: output activation bit width (the paper's ``n``).
      relu: apply ReLU (paper's ReLU unit); False for the logits layer.

    Returns:
      ``[M, N]`` int32 quantized activations in ``[0, 2**bits)`` (or the
      signed range when ``relu=False``).
    """
    m, n = acc.shape
    if bias.shape != (n,):
        raise ValueError(f"bias shape {bias.shape} != ({n},)")
    mult, shift = quantize_fixedpoint_params(scale)
    kernel = functools.partial(
        _fused_sfu_kernel, mult=mult, shift=shift, bits=bits, relu=relu
    )
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, n), lambda _: (0, 0)),
            pl.BlockSpec((n,), lambda _: (0,)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda _: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(acc.astype(jnp.int32), bias.astype(jnp.int32))


def _maxpool_kernel(x_ref, o_ref):
    """2×2/stride-2 max pool — the SFU pooling unit's running max."""
    x = x_ref[...]
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    o_ref[...] = jnp.max(jnp.max(x, axis=4), axis=2)


def maxpool2x2(x, *, interpret: bool = True):
    """Max-pool NHWC int32 activations with a 2×2 window, stride 2.

    H and W must be even (model code pads). Matches the §IV-A.5 pooling
    unit: a counter walks the window, a register keeps the running max.
    """
    b, h, w, c = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"H={h}, W={w} must be even for 2x2 pooling")
    return pl.pallas_call(
        _maxpool_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((b, h, w, c), lambda _: (0, 0, 0, 0))],
        out_specs=pl.BlockSpec((b, h // 2, w // 2, c), lambda _: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h // 2, w // 2, c), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.int32))
