"""L1 Pallas kernel: bit-serial integer matmul — the functional analogue of
PIM-DRAM's in-subarray multiplication + intra-bank adder-tree accumulation.

PIM-DRAM (§III) multiplies n-bit operands column-parallel in a DRAM subarray
by ANDing operand bits and majority-adding partial products; the per-bank
reconfigurable adder tree (§IV-A.1) then reduces the product bits of all
columns belonging to one MAC, and the accumulator (§IV-A.2) shift-adds the
bit-position partial sums.

On this substrate the same decomposition becomes:

  * split activations (unsigned, ``wa`` bits) and weights (two's-complement,
    ``ww`` bits) into bit planes;
  * the AND of a pair of bit planes *is* their 0/1 product, so the per-plane
    partial product reduction is a plain (0/1) matmul — mapping the paper's
    adder tree onto the MXU/ALU reduction;
  * the accumulator applies the ``2^(i+j)`` bit-position weight, with the
    weight MSB plane carrying ``-2^(ww-1)`` (two's complement);
  * the Pallas grid iterates over (activation-bit, weight-bit) plane pairs,
    holding exactly one plane pair in VMEM per grid step — the analogue of
    "operands copied into the compute rows" (§III-B).

Hardware adaptation (DESIGN.md §3): the paper tiles work over DRAM subarray
columns; we tile over (M, N) output blocks via BlockSpec so each grid step is
a VMEM-resident block matmul. ``interpret=True`` everywhere — see aot_recipe.

Exactness: for inputs in range, the kernel computes the *exact* integer
matmul (verified against ``ref.matmul_ref`` by pytest + hypothesis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitserial_matmul", "max_abs_acc", "bits_required"]


def _bitserial_kernel(x_ref, w_ref, o_ref, *, wa: int, ww: int):
    """One grid step: partial product of activation bit-plane ``i`` and
    weight bit-plane ``j``, accumulated into the output block.

    Grid layout is ``(gm, gn, wa, ww)`` with the bit indices innermost so the
    (M, N) output block stays resident while its ``wa*ww`` plane pairs are
    reduced — mirroring one subarray's multiply before the adder-tree pass.
    """
    i = pl.program_id(2)  # activation bit index (LSB = 0)
    j = pl.program_id(3)  # weight bit index

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Bit-plane extraction. Arithmetic shift keeps two's-complement weight
    # bits correct for j < ww (the paper stores operands bit-transposed in
    # DRAM rows; here a plane is a VMEM-resident 0/1 matrix).
    x_plane = ((x_ref[...] >> i) & 1).astype(jnp.int32)
    w_plane = ((w_ref[...] >> j) & 1).astype(jnp.int32)

    # AND of two bit planes == their elementwise product; the contraction is
    # the adder-tree reduction over one MAC's columns.
    partial = jax.lax.dot_general(
        x_plane,
        w_plane,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    # Two's complement: the weight MSB plane carries -2^(ww-1).
    sign = jnp.where(j == ww - 1, jnp.int32(-1), jnp.int32(1))
    o_ref[...] += partial * sign * jnp.left_shift(jnp.int32(1), i + j)


def bits_required(k: int, wa: int, ww: int) -> int:
    """Bits needed to hold a K-deep MAC of wa-bit × ww-bit products.

    Mirrors the accumulator sizing rule of §IV-A.2 (accumulate till the
    2n-th bit arrives, plus log2(K) growth from the adder tree).
    """
    max_acc = max_abs_acc(k, wa, ww)
    return max(1, int(max_acc).bit_length() + 1)  # +1 sign bit


def max_abs_acc(k: int, wa: int, ww: int) -> int:
    """Worst-case |accumulator| value for a K-deep MAC."""
    return k * (2**wa - 1) * (2 ** (ww - 1))


def bitserial_matmul(
    x,
    w,
    *,
    wa: int = 8,
    ww: int = 8,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = True,
):
    """Exact integer matmul ``x @ w`` via bit-serial plane decomposition.

    Args:
      x: ``[M, K]`` int32, unsigned values in ``[0, 2**wa)`` (quantized,
        post-ReLU activations — the paper's activation operand).
      w: ``[K, N]`` int32, two's-complement values in
        ``[-2**(ww-1), 2**(ww-1))``.
      wa/ww: operand bit widths (the paper's ``n``; Fig 17 sweeps this).
      block_m/block_n: output tile sizes (default: whole matrix). M and N
        must be divisible by them; `aot`/model code pads to multiples.
      interpret: must stay True on CPU PJRT (Mosaic custom-calls cannot run
        on the CPU plugin); kept as a parameter for TPU builds.

    Returns:
      ``[M, N]`` int32, exactly equal to the integer matmul. Overflow-safe
      while ``max_abs_acc(K, wa, ww) < 2**31`` (checked).
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x[{m},{k}] @ w[{k2},{n}]")
    if not (1 <= wa <= 15 and 1 <= ww <= 15):
        raise ValueError(f"bit widths out of range: wa={wa} ww={ww}")
    if max_abs_acc(k, wa, ww) >= 2**31:
        raise ValueError(
            f"int32 accumulator overflow risk: K={k} wa={wa} ww={ww}"
        )

    bm = block_m or m
    bn = block_n or n
    if m % bm or n % bn:
        raise ValueError(f"M={m}, N={n} not divisible by blocks ({bm},{bn})")

    grid = (m // bm, n // bn, wa, ww)
    kernel = functools.partial(_bitserial_kernel, wa=wa, ww=ww)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda gm, gn, i, j: (gm, 0)),
            pl.BlockSpec((k, bn), lambda gm, gn, i, j: (0, gn)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda gm, gn, i, j: (gm, gn)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.int32), w.astype(jnp.int32))
