"""Pure-jnp correctness oracles for the L1 kernels.

Every Pallas kernel in this package has an exact reference here; pytest (and
hypothesis sweeps) assert bit-exact agreement. These are also the "golden"
semantics the Rust functional simulator (`rust/src/primitives/`) is tested
against, via shared test vectors emitted by `aot.py`.
"""

import jax.numpy as jnp

__all__ = [
    "matmul_ref",
    "fused_sfu_ref",
    "maxpool2x2_ref",
    "im2col",
    "conv2d_int_ref",
]


def matmul_ref(x, w):
    """Exact integer matmul oracle for `bitserial_matmul`."""
    return x.astype(jnp.int32) @ w.astype(jnp.int32)


def fused_sfu_ref(acc, bias, *, mult: int, shift: int, bits: int, relu: bool):
    """Oracle for `fused_sfu`, given the already-encoded fixed-point params."""
    acc = acc.astype(jnp.int64) + bias.astype(jnp.int64)[None, :]
    if relu:
        acc = jnp.maximum(acc, 0)
    rounded = (acc * mult + (1 << (shift - 1))) >> shift
    hi = (1 << bits) - 1
    lo = 0 if relu else -(1 << (bits - 1))
    return jnp.clip(rounded, lo, hi).astype(jnp.int32)


def maxpool2x2_ref(x):
    """Oracle for `maxpool2x2` (NHWC, 2×2, stride 2)."""
    b, h, w, c = x.shape
    return jnp.max(
        x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4)
    ).astype(jnp.int32)


def im2col(x, kh: int, kw: int, stride: int = 1, pad: int = 0):
    """Unfold NHWC into MAC rows: ``[B*OH*OW, KH*KW*C]``.

    This is exactly the paper's conv→MAC flattening (§IV-B): each output
    pixel of each filter is one MAC of size KH*KW*I, mapped to consecutive
    subarray columns. Padding uses zeros (quantized zero-point is 0).
    """
    b, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h - kh + 2 * pad) // stride + 1
    ow = (w - kw + 2 * pad) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            cols.append(patch.reshape(b * oh * ow, c))
    return jnp.concatenate(cols, axis=1), (b, oh, ow)


def conv2d_int_ref(x, w, stride: int = 1, pad: int = 0):
    """Exact integer conv oracle (NHWC × HWIO → NHWC) via im2col + matmul."""
    kh, kw, ci, co = w.shape
    cols, (b, oh, ow) = im2col(x, kh, kw, stride, pad)
    wmat = w.reshape(kh * kw * ci, co)
    out = matmul_ref(cols, wmat)
    return out.reshape(b, oh, ow, co)
