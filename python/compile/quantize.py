"""Post-training quantization for the PIM-DRAM numeric path.

PIM-DRAM computes with n-bit integer operands stored bit-transposed in DRAM
columns (§III-B); activations are unsigned (post-ReLU), weights are
two's-complement. This module converts a trained float model into exactly
that representation:

  * activations: ``a_q = clamp(round(a / s_a), 0, 2**wa - 1)`` with per-layer
    scales calibrated from training-set percentiles;
  * weights: symmetric per-tensor, ``w_q = clamp(round(w / s_w), -2**(ww-1),
    2**(ww-1) - 1)``;
  * biases: accumulated scale, ``b_q = round(b / (s_in * s_w))``;
  * requantization between banks: fixed-point multiplier + shift (the
    quantize SFU), computed by `kernels.sfu.quantize_fixedpoint_params`.
"""

from dataclasses import dataclass, field

import numpy as np

__all__ = ["QuantParams", "LayerQuant", "quantize_weights", "act_scale"]


def act_scale(samples: np.ndarray, bits: int, percentile: float = 99.9) -> float:
    """Calibrate an unsigned activation scale from observed float values."""
    hi = float(np.percentile(np.maximum(samples, 0.0), percentile))
    hi = max(hi, 1e-6)
    return hi / (2**bits - 1)


def quantize_weights(w: np.ndarray, bits: int):
    """Symmetric per-tensor weight quantization → (int32 weights, scale)."""
    m = float(np.max(np.abs(w)))
    m = max(m, 1e-8)
    scale = m / (2 ** (bits - 1) - 1)
    wq = np.clip(np.round(w / scale), -(2 ** (bits - 1)), 2 ** (bits - 1) - 1)
    return wq.astype(np.int32), scale


@dataclass
class LayerQuant:
    """Quantized parameters for one bank/layer."""

    name: str
    kind: str  # "conv" | "linear"
    weights_q: np.ndarray  # int32, HWIO (conv) or [K, N] (linear)
    bias_q: np.ndarray  # int32 [N], in s_in * s_w scale
    w_scale: float
    in_scale: float
    out_scale: float  # 0.0 for the final (dequantized) layer
    relu: bool
    pool: bool  # 2x2 maxpool after SFU chain
    stride: int = 1
    pad: int = 0

    @property
    def requant_scale(self) -> float:
        """Scale applied by the quantize SFU: s_in*s_w / s_out."""
        if self.out_scale == 0.0:
            raise ValueError(f"{self.name}: final layer has no requant scale")
        return self.in_scale * self.w_scale / self.out_scale

    @property
    def dequant_scale(self) -> float:
        """Scale to float for the final layer: s_in * s_w."""
        return self.in_scale * self.w_scale


@dataclass
class QuantParams:
    """Whole-model quantization: per-layer params + operand bit widths."""

    wa: int
    ww: int
    layers: list = field(default_factory=list)

    def layer(self, name: str) -> LayerQuant:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)
