"""Synthetic 16×16 digits dataset (build-time only).

The paper evaluates on ImageNet-scale networks; the timing experiments in
this repo need only layer *shapes* (public), but the end-to-end numeric
driver needs real data + weights we can generate deterministically offline.
This module renders a 10-class digit dataset from a 5×7 bitmap font with
random shifts, per-image contrast jitter and Gaussian noise — small enough
to train in seconds, hard enough that accuracy is a meaningful signal.

Substitution recorded in DESIGN.md §2 (ImageNet → synthetic digits).
"""

import numpy as np

__all__ = ["make_digits", "GLYPHS", "IMG", "NUM_CLASSES"]

IMG = 16  #: image side
NUM_CLASSES = 10

# 5x7 bitmap font, digits 0..9 (one string row per scanline).
_FONT = [
    ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],  # 0
    ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],  # 1
    ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],  # 2
    ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],  # 3
    ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],  # 4
    ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],  # 5
    ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],  # 6
    ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],  # 7
    ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],  # 8
    ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],  # 9
]

#: 10 glyphs, each a (7, 5) float array in {0, 1}.
GLYPHS = np.array(
    [[[float(c) for c in row] for row in glyph] for glyph in _FONT],
    dtype=np.float32,
)


def _render(rng: np.random.Generator, digit: int) -> np.ndarray:
    """Render one digit: 2× upscale, random offset, jitter, noise."""
    glyph = GLYPHS[digit]
    up = np.kron(glyph, np.ones((2, 2), dtype=np.float32))  # (14, 10)
    img = np.zeros((IMG, IMG), dtype=np.float32)
    dy = rng.integers(0, IMG - up.shape[0] + 1)
    dx = rng.integers(0, IMG - up.shape[1] + 1)
    img[dy : dy + up.shape[0], dx : dx + up.shape[1]] = up
    contrast = rng.uniform(0.6, 1.0)
    img *= contrast
    img += rng.normal(0.0, 0.08, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def make_digits(n: int, seed: int = 0):
    """Generate ``n`` images, balanced across classes.

    Returns:
      images: ``[n, IMG, IMG, 1]`` float32 in [0, 1]
      labels: ``[n]`` int32
    """
    rng = np.random.default_rng(seed)
    labels = np.arange(n, dtype=np.int32) % NUM_CLASSES
    rng.shuffle(labels)
    images = np.stack([_render(rng, int(d)) for d in labels])
    return images[..., None].astype(np.float32), labels
