"""L2: the JAX compute graph — float training model + quantized PIM graph.

Two views of the same network ("PimNet", a small quantized CNN):

  * **Float path** (`init_params` / `apply_float` / `train`): standard JAX
    fwd/bwd used once at build time to obtain trained weights. Runs in
    seconds on CPU.
  * **Quantized PIM path** (`quant_layer_apply` / `apply_quant`): the graph
    the Rust coordinator actually executes — integer activations, bit-serial
    Pallas matmuls (L1), fused SFU chain, maxpool — mirroring one PIM-DRAM
    bank per layer (§IV). `aot.py` lowers each layer (bank) and the full
    graph to HLO text artifacts.

PimNet (input 16×16×1, ~72k params):
  conv1 3×3×1→16 pad1 + ReLU + pool  → 8×8×16
  conv2 3×3×16→32 pad1 + ReLU + pool → 4×4×32
  fc1   512→128 + ReLU
  fc2   128→10 (logits, dequantized)
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import bitserial_matmul, fused_sfu, maxpool2x2
from .kernels.ref import im2col
from .quantize import LayerQuant, QuantParams, act_scale, quantize_weights

__all__ = [
    "LAYER_DEFS",
    "init_params",
    "apply_float",
    "float_layer_activations",
    "train",
    "quantize_model",
    "quant_layer_apply",
    "apply_quant",
    "accuracy",
]


@dataclass(frozen=True)
class LayerDef:
    """Static shape description of one PimNet layer (= one PIM bank)."""

    name: str
    kind: str  # "conv" | "linear"
    in_shape: tuple  # activation shape per-image, NHWC sans batch / [K]
    out_shape: tuple
    kshape: tuple  # HWIO for conv, [K, N] for linear
    relu: bool
    pool: bool
    stride: int = 1
    pad: int = 1


LAYER_DEFS = [
    LayerDef("conv1", "conv", (16, 16, 1), (8, 8, 16), (3, 3, 1, 16), True, True),
    LayerDef("conv2", "conv", (8, 8, 16), (4, 4, 32), (3, 3, 16, 32), True, True),
    LayerDef("fc1", "linear", (512,), (128,), (512, 128), True, False),
    LayerDef("fc2", "linear", (128,), (10,), (128, 10), False, False),
]


# --------------------------------------------------------------------------
# Float path (training only)
# --------------------------------------------------------------------------


def init_params(key) -> dict:
    """He-init float parameters keyed by layer name -> (w, b)."""
    params = {}
    for ld in LAYER_DEFS:
        key, sub = jax.random.split(key)
        fan_in = int(np.prod(ld.kshape[:-1]))
        w = jax.random.normal(sub, ld.kshape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((ld.kshape[-1],), jnp.float32)
        params[ld.name] = (w, b)
    return params


def _float_layer(ld: LayerDef, params, x):
    w, b = params[ld.name]
    if ld.kind == "conv":
        x = jax.lax.conv_general_dilated(
            x, w,
            window_strides=(ld.stride, ld.stride),
            padding=[(ld.pad, ld.pad)] * 2,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
    else:
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        x = x @ w
    x = x + b
    if ld.relu:
        x = jax.nn.relu(x)
    if ld.pool:
        x = -jax.lax.reduce_window(
            -x, jnp.inf, jax.lax.min, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    return x


def apply_float(params, x):
    """Float forward pass: [B,16,16,1] -> [B,10] logits."""
    for ld in LAYER_DEFS:
        x = _float_layer(ld, params, x)
    return x


def float_layer_activations(params, x):
    """Per-layer float *inputs* (pre-layer activations) for calibration."""
    acts = [x]
    for ld in LAYER_DEFS:
        x = _float_layer(ld, params, x)
        acts.append(x)
    return acts


def _loss(params, x, y):
    logits = apply_float(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def train(params, images, labels, *, steps=400, batch=128, lr=2e-3, seed=0):
    """Minimal Adam training loop (optax is unavailable offline)."""
    flat, tree = jax.tree_util.tree_flatten(params)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    b1, b2, eps = 0.9, 0.999, 1e-8

    grad_fn = jax.jit(jax.value_and_grad(_loss))
    rng = np.random.default_rng(seed)
    n = images.shape[0]
    loss_log = []
    for t in range(1, steps + 1):
        idx = rng.integers(0, n, size=batch)
        xb = jnp.asarray(images[idx])
        yb = jnp.asarray(labels[idx])
        loss, grads = grad_fn(tree.unflatten(flat), xb, yb)
        gflat = jax.tree_util.tree_leaves(grads)
        for i, g in enumerate(gflat):
            m[i] = b1 * m[i] + (1 - b1) * g
            v[i] = b2 * v[i] + (1 - b2) * g * g
            mhat = m[i] / (1 - b1**t)
            vhat = v[i] / (1 - b2**t)
            flat[i] = flat[i] - lr * mhat / (jnp.sqrt(vhat) + eps)
        loss_log.append(float(loss))
    return tree.unflatten(flat), loss_log


def accuracy(logits, labels) -> float:
    return float(jnp.mean(jnp.argmax(logits, axis=1) == labels))


# --------------------------------------------------------------------------
# Quantized PIM path
# --------------------------------------------------------------------------


def quantize_model(params, calib_images, *, wa=8, ww=8) -> QuantParams:
    """Post-training quantization calibrated on `calib_images`."""
    acts = float_layer_activations(params, jnp.asarray(calib_images))
    qp = QuantParams(wa=wa, ww=ww)
    # Per-layer input activation scales (unsigned wa-bit).
    scales = [act_scale(np.asarray(a), wa) for a in acts]
    for i, ld in enumerate(LAYER_DEFS):
        w, b = params[ld.name]
        wq, sw = quantize_weights(np.asarray(w), ww)
        s_in = scales[i]
        s_out = 0.0 if i == len(LAYER_DEFS) - 1 else scales[i + 1]
        bq = np.round(np.asarray(b) / (s_in * sw)).astype(np.int32)
        qp.layers.append(
            LayerQuant(
                name=ld.name, kind=ld.kind,
                weights_q=wq, bias_q=bq,
                w_scale=sw, in_scale=s_in, out_scale=s_out,
                relu=ld.relu, pool=ld.pool, stride=ld.stride, pad=ld.pad,
            )
        )
    return qp


def quantize_input(images, qp: QuantParams):
    """Float [B,16,16,1] -> unsigned wa-bit int32 activations."""
    s0 = qp.layers[0].in_scale
    return jnp.clip(
        jnp.round(jnp.asarray(images) / s0), 0, 2**qp.wa - 1
    ).astype(jnp.int32)


def quant_layer_apply(lq: LayerQuant, qp: QuantParams, x, *, interpret=True):
    """One PIM bank's worth of compute on integer activations.

    conv: im2col → bit-serial matmul → +bias/ReLU/BN/quantize (fused SFU)
    → optional 2×2 maxpool. linear: matmul → SFU. The final layer
    dequantizes to float logits instead of requantizing.

    This function *is* the dataflow of §IV-B within one bank; `aot.py`
    lowers it per-layer so the Rust side can pipeline banks explicitly.
    """
    batch = x.shape[0]
    if lq.kind == "conv":
        kh, kw, ci, co = lq.weights_q.shape
        cols, (b, oh, ow) = im2col(x, kh, kw, lq.stride, lq.pad)
        wmat = jnp.asarray(lq.weights_q.reshape(kh * kw * ci, co))
        acc = bitserial_matmul(cols, wmat, wa=qp.wa, ww=qp.ww, interpret=interpret)
    else:
        if x.ndim > 2:
            x = x.reshape(batch, -1)
        acc = bitserial_matmul(
            x, jnp.asarray(lq.weights_q), wa=qp.wa, ww=qp.ww, interpret=interpret
        )

    bias = jnp.asarray(lq.bias_q)
    if lq.out_scale == 0.0:
        # Final layer: dequantize to float logits (host side of the pipe).
        return (acc + bias[None, :]).astype(jnp.float32) * lq.dequant_scale

    y = fused_sfu(
        acc, bias, scale=lq.requant_scale, bits=qp.wa, relu=lq.relu,
        interpret=interpret,
    )
    if lq.kind == "conv":
        kh, kw, ci, co = lq.weights_q.shape
        y = y.reshape(batch, *_conv_out_hw(x, lq), co)
    if lq.pool:
        y = maxpool2x2(y, interpret=interpret)
    return y


def _conv_out_hw(x, lq: LayerQuant):
    h, w = x.shape[1], x.shape[2]
    kh, kw = lq.weights_q.shape[:2]
    oh = (h - kh + 2 * lq.pad) // lq.stride + 1
    ow = (w - kw + 2 * lq.pad) // lq.stride + 1
    return oh, ow


def apply_quant(qp: QuantParams, x_int, *, interpret=True):
    """Full quantized forward: int32 [B,16,16,1] -> float32 [B,10] logits."""
    x = x_int
    for lq in qp.layers:
        x = quant_layer_apply(lq, qp, x, interpret=interpret)
    return x
