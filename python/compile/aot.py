"""AOT build: train → quantize → lower to HLO text artifacts.

This is the *only* entry point that runs Python; it executes once at
``make artifacts`` and produces everything the Rust coordinator needs:

  artifacts/
    manifest.json          — artifact index: shapes, dtypes, quant params
    model.hlo.txt          — full quantized PimNet forward (batch B)
    mvm.hlo.txt            — standalone bit-serial MVM (quickstart/validation)
    layers/l{i}_{name}.hlo.txt — one artifact per layer == per PIM bank,
                             chained by the Rust pipeline (§IV-B dataflow)
    digits_test.bin        — int32-LE quantized test images
    digits_labels.bin      — u8 labels
    testvectors.json       — shared vectors for the Rust functional
                             primitives (bit-level subarray sim) to replay

Interchange format is **HLO text**, not serialized protos: jax ≥ 0.5 emits
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .datasets import make_digits
from .kernels import bitserial_matmul
from .kernels.ref import matmul_ref

__all__ = ["to_hlo_text", "lower_to_hlo_text", "build_artifacts"]


def to_hlo_text(lowered) -> str:
    """Lowered jax computation → XLA HLO text (id-safe interchange).

    ``print_large_constants=True`` is ESSENTIAL: the default HLO printer
    elides big literals as ``constant({...})``, which the text parser on
    the Rust side silently "reparses" into garbage — baked weights would
    be corrupted (this bit us; see EXPERIMENTS.md §Debugging).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def lower_to_hlo_text(fn, *example_args) -> str:
    """jit-lower ``fn`` at the example shapes and return HLO text."""
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def _write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def _layer_artifact(qp, lq, in_shape):
    """Build the single-bank function for one layer and lower it."""

    def bank_fn(x):
        return (M.quant_layer_apply(lq, qp, x),)

    spec = jax.ShapeDtypeStruct(in_shape, jnp.int32)
    return lower_to_hlo_text(bank_fn, spec)


def _mac_geometry(lq, in_shape):
    """MAC count/size for the manifest (cross-checked by rust mapping)."""
    if lq.kind == "conv":
        kh, kw, ci, co = lq.weights_q.shape
        h, w = in_shape[1], in_shape[2]
        oh = (h - kh + 2 * lq.pad) // lq.stride + 1
        ow = (w - kw + 2 * lq.pad) // lq.stride + 1
        return kh * kw * ci, oh * ow * co
    k, n = lq.weights_q.shape
    return k, n


def _test_vectors(seed: int = 7):
    """Small exact-matmul vectors the Rust bit-level simulator replays."""
    rng = np.random.default_rng(seed)
    cases = []
    for wa, ww, m, k, n in [
        (2, 2, 2, 3, 2),
        (4, 4, 3, 5, 4),
        (8, 8, 4, 6, 3),
        (8, 4, 2, 9, 4),
        (3, 7, 3, 4, 2),
    ]:
        x = rng.integers(0, 2**wa, size=(m, k), dtype=np.int64)
        w = rng.integers(-(2 ** (ww - 1)), 2 ** (ww - 1), size=(k, n), dtype=np.int64)
        y_kernel = np.asarray(
            bitserial_matmul(jnp.asarray(x, jnp.int32), jnp.asarray(w, jnp.int32),
                             wa=wa, ww=ww)
        )
        y_ref = np.asarray(matmul_ref(jnp.asarray(x), jnp.asarray(w)))
        assert (y_kernel == y_ref).all(), "kernel/oracle mismatch in testvectors"
        cases.append(
            {
                "wa": wa, "ww": ww, "m": m, "k": k, "n": n,
                "x": x.flatten().tolist(),
                "w": w.flatten().tolist(),
                "y": y_ref.flatten().tolist(),
            }
        )
    return {"matmul_cases": cases}


def build_artifacts(outdir: str, *, steps=400, n_train=2048, n_test=256,
                    batch=8, wa=8, ww=8, seed=0, quick=False):
    if quick:
        steps, n_train, n_test = 60, 512, 64

    print(f"[aot] dataset: {n_train} train / {n_test} test")
    train_x, train_y = make_digits(n_train, seed=seed)
    test_x, test_y = make_digits(n_test, seed=seed + 1)

    print(f"[aot] training PimNet ({steps} steps)...")
    params = M.init_params(jax.random.PRNGKey(seed))
    params, loss_log = M.train(params, train_x, train_y, steps=steps, seed=seed)
    float_acc = M.accuracy(M.apply_float(params, jnp.asarray(test_x)), test_y)
    print(f"[aot] float test accuracy: {float_acc:.3f} "
          f"(loss {loss_log[0]:.3f} -> {loss_log[-1]:.3f})")

    print(f"[aot] quantizing (wa={wa}, ww={ww})...")
    qp = M.quantize_model(params, train_x[:256], wa=wa, ww=ww)

    # Quantized accuracy on a bounded subset (interpret-mode kernels).
    n_eval = min(n_test, 64)
    xq_eval = M.quantize_input(test_x[:n_eval], qp)
    quant_fwd = jax.jit(lambda x: M.apply_quant(qp, x))
    logits_q = np.concatenate(
        [np.asarray(quant_fwd(xq_eval[i : i + batch]))
         for i in range(0, n_eval, batch)]
    )
    quant_acc = M.accuracy(jnp.asarray(logits_q), test_y[:n_eval])
    print(f"[aot] quant test accuracy ({n_eval} imgs): {quant_acc:.3f}")

    # ---- lower artifacts -------------------------------------------------
    print("[aot] lowering HLO artifacts...")
    layers_meta = []
    in_shape = (batch, 16, 16, 1)
    for i, lq in enumerate(qp.layers):
        hlo = _layer_artifact(qp, lq, in_shape)
        rel = f"layers/l{i}_{lq.name}.hlo.txt"
        _write(os.path.join(outdir, rel), hlo)
        # output shape by abstract evaluation
        out_aval = jax.eval_shape(
            lambda x: M.quant_layer_apply(lq, qp, x),
            jax.ShapeDtypeStruct(in_shape, jnp.int32),
        )
        mac_size, num_macs = _mac_geometry(lq, in_shape)
        layers_meta.append(
            {
                "name": lq.name,
                "file": rel,
                "kind": lq.kind,
                "in_shape": list(in_shape),
                "out_shape": list(out_aval.shape),
                "out_dtype": "f32" if out_aval.dtype == jnp.float32 else "i32",
                "mac_size": int(mac_size),
                "num_macs": int(num_macs),
                "relu": bool(lq.relu),
                "pool": bool(lq.pool),
                "w_scale": float(lq.w_scale),
                "in_scale": float(lq.in_scale),
                "out_scale": float(lq.out_scale),
            }
        )
        in_shape = tuple(out_aval.shape)

    full_hlo = lower_to_hlo_text(
        lambda x: (M.apply_quant(qp, x),),
        jax.ShapeDtypeStruct((batch, 16, 16, 1), jnp.int32),
    )
    _write(os.path.join(outdir, "model.hlo.txt"), full_hlo)

    # Standalone parametric MVM (both operands runtime inputs).
    mvm_m, mvm_k, mvm_n = 8, 64, 64
    mvm_hlo = lower_to_hlo_text(
        lambda x, w: (bitserial_matmul(x, w, wa=wa, ww=ww),),
        jax.ShapeDtypeStruct((mvm_m, mvm_k), jnp.int32),
        jax.ShapeDtypeStruct((mvm_k, mvm_n), jnp.int32),
    )
    _write(os.path.join(outdir, "mvm.hlo.txt"), mvm_hlo)

    # ---- per-layer debug activations (cross-layer validation) -----------
    # The Rust runtime replays the first batch through the per-layer
    # artifacts and must reproduce these exactly (layout-sensitive!).
    dbg_x = M.quantize_input(test_x[:batch], qp)
    act = dbg_x
    for i, lq in enumerate(qp.layers):
        act = M.quant_layer_apply(lq, qp, act)
        arr = np.asarray(act)
        fname = f"debug_act_l{i}.bin"
        if arr.dtype.kind == "f":
            arr.astype("<f4").tofile(os.path.join(outdir, fname))
        else:
            arr.astype("<i4").tofile(os.path.join(outdir, fname))
    dbg_x_np = np.asarray(dbg_x, dtype="<i4")
    dbg_x_np.tofile(os.path.join(outdir, "debug_input.bin"))
    print("  wrote debug_input.bin / debug_act_l*.bin")

    # ---- datasets (raw LE binary; parsed by rust/src/runtime) -----------
    xq_all = np.asarray(M.quantize_input(test_x, qp), dtype="<i4")
    with open(os.path.join(outdir, "digits_test.bin"), "wb") as f:
        f.write(xq_all.tobytes())
    with open(os.path.join(outdir, "digits_labels.bin"), "wb") as f:
        f.write(test_y.astype(np.uint8).tobytes())
    print(f"  wrote digits_test.bin / digits_labels.bin ({n_test} images)")

    with open(os.path.join(outdir, "testvectors.json"), "w") as f:
        json.dump(_test_vectors(), f)

    manifest = {
        "version": 1,
        "wa": wa, "ww": ww, "batch": batch,
        "input_scale": qp.layers[0].in_scale,
        "model_hlo": "model.hlo.txt",
        "mvm_hlo": "mvm.hlo.txt",
        "mvm_shape": [mvm_m, mvm_k, mvm_n],
        "test_images": {
            "file": "digits_test.bin", "count": int(n_test),
            "shape": [16, 16, 1], "dtype": "i32",
        },
        "test_labels": {"file": "digits_labels.bin", "count": int(n_test)},
        "float_test_accuracy": float(float_acc),
        "quant_test_accuracy": float(quant_acc),
        "train_loss_first": float(loss_log[0]),
        "train_loss_last": float(loss_log[-1]),
        "layers": layers_meta,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json written; {len(layers_meta)} layer artifacts")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--wa", type=int, default=8)
    ap.add_argument("--ww", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="fast build for CI/tests (fewer steps, less data)")
    # legacy flag kept for Makefile compatibility
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    outdir = args.outdir
    if args.out:
        outdir = os.path.dirname(os.path.abspath(args.out))
    build_artifacts(
        outdir, steps=args.steps, batch=args.batch,
        wa=args.wa, ww=args.ww, seed=args.seed, quick=args.quick,
    )


if __name__ == "__main__":
    main()
