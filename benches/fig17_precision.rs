//! E7 — Fig 17: execution time vs operand bit precision.
//!
//! The multiply cost is the paper's closed form (3n² + 4(n-1)³ + 4(n-1)
//! AAPs for n > 2), so per-image time should grow ≈ cubically in n. The
//! bench prints per-network steady-state time for n ∈ {2, 4, 8, 16} and
//! checks the growth exponent. Every point is an `api::Spec` variant
//! through one `api::Job` per network; networks sweep in parallel
//! (`par_sweep`), precision points share the job's incremental session.

use pim_dram::api::{Job, Spec};
use pim_dram::bench_harness::{banner, par_sweep, Bencher};
use pim_dram::primitives::paper_mul_aaps;
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets::paper_networks;

fn main() {
    banner("Fig 17", "runtime vs operand bit precision");
    let bits = [2usize, 4, 8, 16];
    let nets = paper_networks();

    let series: Vec<(String, Vec<f64>)> = par_sweep(nets.len(), |i| {
        let net = &nets[i];
        let base = Spec::builtin(&net.name).with_preset("paper_favorable");
        let job = Job::new(base.clone()).expect("spec resolves");
        let mut session = job.session();
        let times: Vec<f64> = bits
            .iter()
            .map(|&n| {
                let r = job
                    .report_variant(&mut session, &base.clone().with_precision(n))
                    .unwrap();
                r.cycle_ns / 1e6
            })
            .collect();
        (net.name.clone(), times)
    });

    let mut t = Table::new(&["network", "2-bit", "4-bit", "8-bit", "16-bit"])
        .aligns(&[
            Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        ]);
    for (name, times) in &series {
        let mut row = vec![name.clone()];
        for ms in times {
            row.push(format!("{ms:.3} ms"));
        }
        t.row(&row);
    }
    println!("{}", t.render());
    println!("multiply AAP counts: {:?}", bits.map(|n| paper_mul_aaps(n as u64)));

    // Shape: monotone growth; 16b/8b ratio should approach the AAP ratio
    // (the multiply dominates at high n).
    let aap_ratio = paper_mul_aaps(16) as f64 / paper_mul_aaps(8) as f64;
    for (name, times) in &series {
        assert!(
            times.windows(2).all(|w| w[1] > w[0]),
            "{name}: time must grow with precision"
        );
        let r = times[3] / times[2];
        println!("{name}: 16b/8b time ratio {r:.2} (AAP ratio {aap_ratio:.2})");
        assert!(r > 2.0, "{name}: growth too flat ({r:.2})");
    }

    let mut b = Bencher::from_env();
    let job = Job::new(
        Spec::builtin("alexnet").with_preset("paper_favorable").with_precision(16),
    )
    .expect("spec resolves");
    b.bench("Job::report(alexnet) 16-bit", || {
        job.report().unwrap().total_aaps
    });
    let mut session = job.session();
    b.bench("session.report(alexnet) 16-bit", || {
        session.report(job.config()).unwrap().total_aaps
    });
}
