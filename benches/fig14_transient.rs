//! E2 — Fig 14: transient analysis of the in-DRAM AND for all input
//! combinations. Writes the waveform CSV to `target/fig14_transients.csv`
//! and prints the rail-to-rail summary the figure shows: for (1,1) the
//! BL/S1/S2 nodes reach VDD, all other cases collapse to GND.

use pim_dram::bench_harness::{banner, Bencher};
use pim_dram::circuit::{simulate_and, AndInputs, CircuitParams};

fn main() {
    banner("Fig 14", "SPICE-style transients of the AND primitive");
    let p = CircuitParams::cmos65nm();

    let mut csv = String::new();
    for inputs in AndInputs::all_cases() {
        let (wf, phase) = simulate_and(&p, inputs, None);
        println!(
            "case ({}): BL {:.3} V, S1 {:.3} V, S2 {:.3} V (expected {})",
            inputs.label(),
            wf.final_value("BL").unwrap(),
            wf.final_value("S1").unwrap(),
            wf.final_value("S2").unwrap(),
            if inputs.expected() { "VDD" } else { "GND" }
        );
        println!("{}", wf.ascii("BL", 8, 64));
        println!(
            "  phases: share @{:.1} ns, sense @{:.1} ns, restore @{:.1} ns",
            phase.share_start_ns, phase.sense_start_ns, phase.restore_start_ns
        );
        csv.push_str(&format!("# case {}\n", inputs.label()));
        csv.push_str(&wf.to_csv());
        // The figure's observable: rail-to-rail regeneration.
        let rail = if inputs.expected() { p.vdd } else { 0.0 };
        for node in ["BL", "S1", "S2"] {
            assert!(
                (wf.final_value(node).unwrap() - rail).abs() < 0.05 * p.vdd,
                "case {} node {node} did not reach its rail",
                inputs.label()
            );
        }
    }
    let out = "target/fig14_transients.csv";
    std::fs::create_dir_all("target").ok();
    std::fs::write(out, csv).unwrap();
    println!("waveforms written to {out}");

    let mut b = Bencher::from_env();
    b.bench("transient(1,1) full 4-phase", || {
        simulate_and(&p, AndInputs { a: true, b: true }, None).0.len()
    });
}
