//! §Perf — wall-clock benchmarks of the simulator hot paths (the
//! optimization targets in DESIGN.md §8). These are the numbers the
//! EXPERIMENTS.md §Perf before/after table tracks, and every run writes
//! the machine-readable `BENCH_PERF.json` at the repo root so the perf
//! trajectory is diffable.
//!
//! Headline target: a ks × grid sweep over vgg16 — the fig16/design-space
//! call pattern — evaluated twice, once with fresh `simulate()` per point
//! and once through one incremental `SimSession`. Full (non-FAST) runs
//! assert the session path is ≥ 3× faster.
//!
//! Other targets:
//!   * `simulate()` full networks: the per-experiment unit of work.
//!   * `SimSession::report`: the steady-state incremental path.
//!   * `in_dram_mul`: the functional bit-level multiply (tests + examples).
//!   * `maj5`: the inner bit-parallel majority kernel.
//!   * Monte Carlo sample rate (fig15 calls 400k samples).
//!   * `BankPipeline::mvm`: the cross-validation path.

use pim_dram::arch::{adder_tree::AdderTree, bank_pim::BankPipeline};
use pim_dram::bench_harness::{banner, write_bench_json, Bencher};
use pim_dram::circuit::{run_monte_carlo, CircuitParams};
use pim_dram::dram::BitRow;
use pim_dram::mapping::{map_network, MapConfig};
use pim_dram::primitives::{mul::in_dram_mul, PimSubarray};
use pim_dram::sim::{simulate, SimConfig, SimSession};
use pim_dram::util::rng::Rng;
use pim_dram::workloads::nets::{resnet18, vgg16};

/// The fig16/design-space call pattern: parallelism × grid points over
/// one network, all sharing the pricing-relevant config.
fn sweep_configs() -> Vec<SimConfig> {
    let mut cfgs = Vec::new();
    for &(channels, ranks) in &[(1usize, 4usize), (2, 4), (4, 4)] {
        for &k in &[1usize, 2, 4, 8] {
            cfgs.push(
                SimConfig::paper_favorable(8)
                    .with_ks(vec![k])
                    .with_grid(channels, ranks),
            );
        }
    }
    cfgs
}

fn main() {
    banner("Perf", "simulator hot-path wall-clock benchmarks");
    let fast = std::env::var("PIM_BENCH_FAST").is_ok();
    let mut b = Bencher::from_env();
    let vgg = vgg16();
    let res = resnet18();

    // ---- headline: sweep-style workload, fresh vs incremental ----------
    let cfgs = sweep_configs();
    let fresh = b
        .bench_items("sweep vgg16 ks×grid (fresh simulate)", cfgs.len() as f64, || {
            let mut acc = 0u64;
            for cfg in &cfgs {
                acc ^= simulate(&vgg, cfg).unwrap().total_aaps;
            }
            acc
        })
        .clone();
    let mut sweep_session = SimSession::new(&vgg);
    let warm = b
        .bench_items("sweep vgg16 ks×grid (SimSession)", cfgs.len() as f64, || {
            let mut acc = 0u64;
            for cfg in &cfgs {
                acc ^= sweep_session.report(cfg).unwrap().total_aaps;
            }
            acc
        })
        .clone();
    let speedup = fresh.mean.as_secs_f64() / warm.mean.as_secs_f64();
    let (hits, misses) = sweep_session.cache_stats();
    println!(
        "sweep speedup: {speedup:.1}x (session cache: {hits} hits / {misses} \
         misses over the timed runs)"
    );
    if !fast {
        assert!(
            speedup >= 3.0,
            "incremental sweep must be ≥ 3x faster than fresh simulate() \
             (got {speedup:.2}x)"
        );
    }

    // ---- full-network simulation (the experiment unit) ------------------
    b.bench("simulate(vgg16, favorable)", || {
        simulate(&vgg, &SimConfig::paper_favorable(8)).unwrap().total_aaps
    });
    b.bench("simulate(resnet18, conservative)", || {
        simulate(&res, &SimConfig::conservative(8)).unwrap().total_aaps
    });
    let res_cfg = SimConfig::conservative(8);
    let mut res_session = SimSession::new(&res);
    b.bench("session.report(resnet18, conservative)", || {
        res_session.report(&res_cfg).unwrap().total_aaps
    });
    b.bench("map_network(vgg16)", || {
        map_network(
            &vgg,
            &MapConfig::uniform(pim_dram::dram::DramGeometry::paper_ideal(), 8, 1),
        )
        .unwrap()
        .layers
        .len()
    });

    // Bit-level functional multiply, 4096 columns (one subarray row-width).
    let mut pim = PimSubarray::new(8, 4096, 1);
    let mut rng = Rng::new(3);
    for col in 0..4096 {
        pim.write_pair(col, 0, rng.int_range(0, 255) as u64, rng.int_range(0, 255) as u64);
    }
    b.bench_items("in_dram_mul 8b x 4096 cols", 4096.0, || {
        let mut p = pim.clone();
        in_dram_mul(&mut p, 0);
        p.stats.total_aaps()
    });

    // maj5 over a full row.
    let rows: Vec<BitRow> = (0..5)
        .map(|r| BitRow::from_fn(4096, |c| (c * 31 + r * 17) % 3 == 0))
        .collect();
    b.bench_items("maj5 4096 columns", 4096.0, || {
        BitRow::maj5([&rows[0], &rows[1], &rows[2], &rows[3], &rows[4]]).count_ones()
    });

    // Monte Carlo sample rate.
    let p = CircuitParams::cmos65nm();
    b.bench_items("monte_carlo 40k samples", 40_000.0, || {
        run_monte_carlo(&p, 10_000, 9).failures
    });

    // Cross-validation MVM (subarray multiply + tree + accumulate).
    let bp = BankPipeline::new(AdderTree::new(1024), 8);
    let x: Vec<u64> = (0..64).map(|_| rng.int_range(0, 255) as u64).collect();
    let w: Vec<Vec<i64>> = (0..64)
        .map(|_| (0..16).map(|_| rng.int_range(-128, 127)).collect())
        .collect();
    b.bench_items("bank_pipeline mvm 64x16 (8b)", (64 * 16) as f64, || {
        bp.mvm(&x, &w).len()
    });

    // ---- machine-readable perf record -----------------------------------
    // Default lands at the repo root regardless of `cargo bench`'s cwd.
    let json_path = std::env::var("PIM_BENCH_JSON").unwrap_or_else(|_| {
        format!("{}/../BENCH_PERF.json", env!("CARGO_MANIFEST_DIR"))
    });
    write_bench_json(
        &json_path,
        "regenerate with: cargo bench --bench perf_hotpath \
         (PIM_BENCH_FAST=1 for smoke runs)",
        b.results(),
        &[("sweep_speedup_x", speedup)],
    )
    .expect("writing BENCH_PERF.json");
    println!("\nwrote {json_path}  (record the table in EXPERIMENTS.md §Perf)");
}
