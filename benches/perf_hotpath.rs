//! §Perf — wall-clock benchmarks of the simulator hot paths (the L3
//! optimization targets in DESIGN.md §8). These are the numbers the
//! EXPERIMENTS.md §Perf before/after table tracks.
//!
//! Targets:
//!   * `simulate()` full networks: the per-experiment unit of work — the
//!     fig16/fig17 sweeps call it dozens of times.
//!   * `in_dram_mul`: the functional bit-level multiply (tests + examples).
//!   * `maj5`: the inner bit-parallel majority kernel.
//!   * Monte Carlo sample rate (fig15 calls 400k samples).
//!   * `BankPipeline::mvm`: the cross-validation path.

use pim_dram::arch::{adder_tree::AdderTree, bank_pim::BankPipeline};
use pim_dram::bench_harness::{banner, Bencher};
use pim_dram::circuit::{run_monte_carlo, CircuitParams};
use pim_dram::dram::BitRow;
use pim_dram::mapping::{map_network, MapConfig};
use pim_dram::primitives::{mul::in_dram_mul, PimSubarray};
use pim_dram::sim::{simulate, SimConfig};
use pim_dram::util::rng::Rng;
use pim_dram::workloads::nets::{resnet18, vgg16};

fn main() {
    banner("Perf", "simulator hot-path wall-clock benchmarks");
    let mut b = Bencher::from_env();

    // Full-network simulation (the experiment unit).
    let vgg = vgg16();
    let res = resnet18();
    b.bench("simulate(vgg16, favorable)", || {
        simulate(&vgg, &SimConfig::paper_favorable(8)).unwrap().total_aaps
    });
    b.bench("simulate(resnet18, conservative)", || {
        simulate(&res, &SimConfig::conservative(8)).unwrap().total_aaps
    });
    b.bench("map_network(vgg16)", || {
        map_network(
            &vgg,
            &MapConfig::uniform(pim_dram::dram::DramGeometry::paper_ideal(), 8, 1),
        )
        .unwrap()
        .layers
        .len()
    });

    // Bit-level functional multiply, 4096 columns (one subarray row-width).
    let mut pim = PimSubarray::new(8, 4096, 1);
    let mut rng = Rng::new(3);
    for col in 0..4096 {
        pim.write_pair(col, 0, rng.int_range(0, 255) as u64, rng.int_range(0, 255) as u64);
    }
    b.bench_items("in_dram_mul 8b x 4096 cols", 4096.0, || {
        let mut p = pim.clone();
        in_dram_mul(&mut p, 0);
        p.stats.total_aaps()
    });

    // maj5 over a full row.
    let rows: Vec<BitRow> = (0..5)
        .map(|r| BitRow::from_fn(4096, |c| (c * 31 + r * 17) % 3 == 0))
        .collect();
    b.bench_items("maj5 4096 columns", 4096.0, || {
        BitRow::maj5([&rows[0], &rows[1], &rows[2], &rows[3], &rows[4]]).count_ones()
    });

    // Monte Carlo sample rate.
    let p = CircuitParams::cmos65nm();
    b.bench_items("monte_carlo 40k samples", 40_000.0, || {
        run_monte_carlo(&p, 10_000, 9).failures
    });

    // Cross-validation MVM (subarray multiply + tree + accumulate).
    let bp = BankPipeline::new(AdderTree::new(1024), 8);
    let x: Vec<u64> = (0..64).map(|_| rng.int_range(0, 255) as u64).collect();
    let w: Vec<Vec<i64>> = (0..64)
        .map(|_| (0..16).map(|_| rng.int_range(-128, 127)).collect())
        .collect();
    b.bench_items("bank_pipeline mvm 64x16 (8b)", (64 * 16) as f64, || {
        bp.mvm(&x, &w).len()
    });

    println!("\n(record these in EXPERIMENTS.md §Perf)");
}
