//! §Perf — wall-clock benchmarks of the simulator hot paths (the
//! optimization targets in DESIGN.md §8). These are the numbers the
//! EXPERIMENTS.md §Perf trajectory table tracks, and every run writes the
//! machine-readable `BENCH_PERF.json` at the repo root so the perf
//! trajectory is diffable. The run also diffs itself against the
//! *committed* `BENCH_PERF.json` (or `PIM_BENCH_BASELINE`) and fails on a
//! > 25% ns/op regression of any shared target — unless the baseline is
//! the empty seed placeholder, which skips the gate.
//!
//! Named targets (required in every run, fast or full — `PIM_BENCH_FAST=1`
//! shrinks iteration counts but never skips a target):
//!   * `price_layer` — per-layer pricing over a pre-mapped vgg16.
//!   * `lower` — grid lowering (map + layout) of vgg16.
//!   * `session_hit` — warm `SimSession::report` (pure cache-hit read).
//!   * `serve_dispatch` — one `classify()` through a running device pool.
//!   * `batched_serve` — 8 admission requests priced in one session pass;
//!     full runs assert it is ≥ 2× faster than `serve_per_request`.
//!
//! Headline sweep: a ks × grid sweep over vgg16 — the fig16/design-space
//! call pattern — evaluated twice, once with fresh `simulate()` per point
//! (`sweep_fresh`) and once through one incremental `SimSession`
//! (`sweep_session`). Full runs assert the session path is ≥ 3× faster.
//!
//! Legacy targets (kept for trend continuity): full-network `simulate()`,
//! `map_network`, `in_dram_mul`, `maj5`, Monte Carlo sample rate, and
//! `BankPipeline::mvm`.

use std::time::Duration;

use pim_dram::arch::{adder_tree::AdderTree, bank_pim::BankPipeline};
use pim_dram::bench_harness::{
    banner, check_regression, read_baseline, write_bench_json, Bencher,
};
use pim_dram::circuit::{run_monte_carlo, CircuitParams};
use pim_dram::coordinator::{MultiDeviceServer, Policy, PoolConfig, SimBackend};
use pim_dram::dram::BitRow;
use pim_dram::mapping::{map_network, MapConfig};
use pim_dram::plan::{self, ShardPolicy};
use pim_dram::primitives::{mul::in_dram_mul, PimSubarray};
use pim_dram::sim::{price_layers, simulate, SimConfig, SimSession};
use pim_dram::util::rng::Rng;
use pim_dram::workloads::nets::{pimnet, resnet18, vgg16};

/// Every run — fast or full — must measure these. A fast-mode change that
/// silently drops one fails here, not in a later CI grep.
const REQUIRED: [&str; 5] =
    ["price_layer", "lower", "session_hit", "serve_dispatch", "batched_serve"];

/// The fig16/design-space call pattern: parallelism × grid points over
/// one network, all sharing the pricing-relevant config.
fn sweep_configs() -> Vec<SimConfig> {
    let mut cfgs = Vec::new();
    for &(channels, ranks) in &[(1usize, 4usize), (2, 4), (4, 4)] {
        for &k in &[1usize, 2, 4, 8] {
            cfgs.push(
                SimConfig::paper_favorable(8)
                    .with_ks(vec![k])
                    .with_grid(channels, ranks),
            );
        }
    }
    cfgs
}

/// An admission batch of serve-pricing requests: same network and pricing
/// config (so the per-layer cache is shared), different plan shapes —
/// the pool-resizing call pattern the serve path batches.
fn serve_batch() -> Vec<SimConfig> {
    let mut cfgs = Vec::new();
    for &(channels, ranks) in &[(1usize, 4usize), (2, 4), (4, 4), (8, 4)] {
        for &shard in &[ShardPolicy::Replicate, ShardPolicy::LayerSplit] {
            cfgs.push(
                SimConfig::paper_favorable(8)
                    .with_grid(channels, ranks)
                    .with_shard(shard),
            );
        }
    }
    cfgs
}

fn main() {
    banner("Perf", "simulator hot-path wall-clock benchmarks");
    let fast = std::env::var("PIM_BENCH_FAST").is_ok();
    let mut b = Bencher::from_env();
    let vgg = vgg16();
    let res = resnet18();

    // ---- named hot-path targets ----------------------------------------
    let map_cfg =
        MapConfig::uniform(pim_dram::dram::DramGeometry::paper_ideal(), 8, 1);
    let mapped = map_network(&vgg, &map_cfg).unwrap();
    let price_cfg = SimConfig::paper_favorable(8);
    b.bench_items("price_layer", vgg.layers.len() as f64, || {
        price_layers(&vgg, &mapped, &price_cfg).len()
    });

    b.bench("lower", || {
        plan::lower(&vgg, &map_cfg, ShardPolicy::Replicate).unwrap().devices.len()
    });

    let res_cfg = SimConfig::conservative(8);
    let mut res_session = SimSession::new(&res);
    res_session.report(&res_cfg).unwrap(); // prime: timed runs are pure hits
    b.bench("session_hit", || res_session.report(&res_cfg).unwrap().total_aaps);

    // One dispatched request through a live 2-device pool (pimnet keeps the
    // deterministic logit math cheap; the dispatch/queue overhead is what
    // this times).
    let pn = pimnet();
    let serve_cfg = SimConfig::conservative(8);
    let mut pn_session = SimSession::new(&pn);
    let backend = SimBackend::from_session(&mut pn_session, &serve_cfg, 1).unwrap();
    let image: Vec<i32> =
        (0..pn.layers[0].in_elems()).map(|i| (i % 7) as i32).collect();
    let server = MultiDeviceServer::start(
        PoolConfig {
            devices: 2,
            policy: Policy::RoundRobin,
            batch_window: Duration::ZERO,
            ..PoolConfig::default()
        },
        move |_| Ok(backend.clone()),
    )
    .unwrap();
    b.bench("serve_dispatch", || server.classify(image.clone()).unwrap().class);
    server.shutdown();

    // Batched serve pricing: 8 admission requests, per-request sessions vs
    // one shared session pass. Both start cold every iteration — the win
    // measured is the shared cache fill, not warm-vs-cold.
    let batch = serve_batch();
    let per_request = b
        .bench_items("serve_per_request", batch.len() as f64, || {
            let mut acc = 0u64;
            for cfg in &batch {
                let mut session = SimSession::new(&vgg);
                acc ^= session.report(cfg).unwrap().total_aaps;
            }
            acc
        })
        .clone();
    let batched = b
        .bench_items("batched_serve", batch.len() as f64, || {
            let mut session = SimSession::new(&vgg);
            SimBackend::price_batch(&mut session, &batch)
                .iter()
                .map(|r| r.as_ref().unwrap().total_aaps)
                .fold(0u64, |a, v| a ^ v)
        })
        .clone();
    let batched_speedup = per_request.mean.as_secs_f64() / batched.mean.as_secs_f64();
    println!("batched serve-pricing speedup: {batched_speedup:.1}x over per-request");
    if !fast {
        assert!(
            batched_speedup >= 2.0,
            "batched serve pricing must be ≥ 2x faster than the per-request \
             loop (got {batched_speedup:.2}x)"
        );
    }

    // ---- headline: sweep-style workload, fresh vs incremental ----------
    let cfgs = sweep_configs();
    let fresh = b
        .bench_items("sweep_fresh", cfgs.len() as f64, || {
            let mut acc = 0u64;
            for cfg in &cfgs {
                acc ^= simulate(&vgg, cfg).unwrap().total_aaps;
            }
            acc
        })
        .clone();
    let mut sweep_session = SimSession::new(&vgg);
    let warm = b
        .bench_items("sweep_session", cfgs.len() as f64, || {
            let mut acc = 0u64;
            for cfg in &cfgs {
                acc ^= sweep_session.report(cfg).unwrap().total_aaps;
            }
            acc
        })
        .clone();
    let speedup = fresh.mean.as_secs_f64() / warm.mean.as_secs_f64();
    let (hits, misses) = sweep_session.cache_stats();
    println!(
        "sweep speedup: {speedup:.1}x (session cache: {hits} hits / {misses} \
         misses over the timed runs)"
    );
    if !fast {
        assert!(
            speedup >= 3.0,
            "incremental sweep must be ≥ 3x faster than fresh simulate() \
             (got {speedup:.2}x)"
        );
    }

    // ---- full-network simulation (the experiment unit) ------------------
    b.bench("simulate(vgg16, favorable)", || {
        simulate(&vgg, &SimConfig::paper_favorable(8)).unwrap().total_aaps
    });
    b.bench("simulate(resnet18, conservative)", || {
        simulate(&res, &SimConfig::conservative(8)).unwrap().total_aaps
    });
    b.bench("map_network(vgg16)", || {
        map_network(&vgg, &map_cfg).unwrap().layers.len()
    });

    // Bit-level functional multiply, 4096 columns (one subarray row-width).
    let mut pim = PimSubarray::new(8, 4096, 1);
    let mut rng = Rng::new(3);
    for col in 0..4096 {
        pim.write_pair(col, 0, rng.int_range(0, 255) as u64, rng.int_range(0, 255) as u64);
    }
    b.bench_items("in_dram_mul 8b x 4096 cols", 4096.0, || {
        let mut p = pim.clone();
        in_dram_mul(&mut p, 0);
        p.stats.total_aaps()
    });

    // maj5 over a full row.
    let rows: Vec<BitRow> = (0..5)
        .map(|r| BitRow::from_fn(4096, |c| (c * 31 + r * 17) % 3 == 0))
        .collect();
    b.bench_items("maj5 4096 columns", 4096.0, || {
        BitRow::maj5([&rows[0], &rows[1], &rows[2], &rows[3], &rows[4]]).count_ones()
    });

    // Monte Carlo sample rate.
    let p = CircuitParams::cmos65nm();
    b.bench_items("monte_carlo 40k samples", 40_000.0, || {
        run_monte_carlo(&p, 10_000, 9).failures
    });

    // Cross-validation MVM (subarray multiply + tree + accumulate).
    let bp = BankPipeline::new(AdderTree::new(1024), 8);
    let x: Vec<u64> = (0..64).map(|_| rng.int_range(0, 255) as u64).collect();
    let w: Vec<Vec<i64>> = (0..64)
        .map(|_| (0..16).map(|_| rng.int_range(-128, 127)).collect())
        .collect();
    b.bench_items("bank_pipeline mvm 64x16 (8b)", (64 * 16) as f64, || {
        bp.mvm(&x, &w).len()
    });

    // ---- structural fast-mode guard -------------------------------------
    for name in REQUIRED {
        assert!(
            b.results().iter().any(|m| m.name == name),
            "required perf target `{name}` was not measured — fast mode may \
             shrink iteration counts but never skip a target"
        );
    }

    // ---- machine-readable perf record + regression gate ------------------
    // Default lands at the repo root regardless of `cargo bench`'s cwd.
    let json_path = std::env::var("PIM_BENCH_JSON").unwrap_or_else(|_| {
        format!("{}/../BENCH_PERF.json", env!("CARGO_MANIFEST_DIR"))
    });
    // The committed record is the baseline unless CI saved it elsewhere —
    // read it *before* overwriting.
    let baseline_path =
        std::env::var("PIM_BENCH_BASELINE").unwrap_or_else(|_| json_path.clone());
    let baseline = read_baseline(&baseline_path);
    let baseline_pairs = baseline.clone().unwrap_or_default();
    write_bench_json(
        &json_path,
        "regenerate with: cargo bench --bench perf_hotpath \
         (PIM_BENCH_FAST=1 for smoke runs)",
        b.results(),
        &[
            ("sweep_speedup_x", speedup),
            ("batched_serve_speedup_x", batched_speedup),
        ],
        &baseline_pairs,
    )
    .expect("writing BENCH_PERF.json");
    println!("\nwrote {json_path}  (record the table in EXPERIMENTS.md §Perf)");

    match baseline {
        None => println!(
            "no perf baseline at {baseline_path} (missing or empty seed) — \
             regression gate skipped"
        ),
        Some(base) => match check_regression(&base, b.results(), 0.25) {
            Ok(()) => println!(
                "regression gate: all shared targets within +25% of {baseline_path}"
            ),
            Err(report) => {
                eprintln!("perf regression vs {baseline_path}:\n{report}");
                std::process::exit(1);
            }
        },
    }
}
