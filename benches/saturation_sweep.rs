//! Saturation sweep: open-loop arrival rate × fleet size × device mix on
//! the deterministic virtual-time fleet (`coordinator::chaos`), with
//! service times priced by the real timing model (`api::Job`) — not made
//! up. The sweep locates the saturation knee (goodput-rate plateau / p99
//! blow-up) per fleet, and the two serving-at-scale claims double as
//! regression assertions in full (non-FAST) runs:
//!
//!   * On a mixed edge/cloud fleet under deadline pressure, the
//!     backlog-aware router achieves strictly higher goodput than
//!     round-robin at at least one arrival rate (`backlog_goodput_gain_x`).
//!   * Searched-plan dispatch (`run.mapper: "search"`) is never worse on
//!     end-to-end serve p50 at any swept rate, and strictly faster at at
//!     least one (`searched_p50_speedup_x`) — on whichever of
//!     mobilenet_mini/tinyformer the mapping search improves more.
//!
//! Every cell accounts for every offered request, and the whole sweep is
//! bitwise reproducible (virtual time, pinned seeds). Wall-clock targets
//! (`saturation_cell`, `searched_fleet_price`) land in the shared
//! `BENCH_PERF.json` next to the `perf_hotpath` ones.

use pim_dram::api::{Job, Mapper, Spec};
use pim_dram::bench_harness::{
    banner, check_regression, read_baseline, write_bench_json, Bencher,
};
use pim_dram::coordinator::{
    simulate_fleet, ArrivalKind, FaultSpec, FleetConfig, FleetReport, Policy,
    ResilienceSpec, TrafficSpec,
};
use pim_dram::util::table::{Align, Table};

/// Every run — fast or full — must measure these. A fast-mode change that
/// silently drops one fails here, not in a later CI grep.
const REQUIRED: [&str; 2] = ["saturation_cell", "searched_fleet_price"];

/// Per-image service time (ns) of `net` on `preset`, from the timing
/// model — searched through `mapopt` when asked.
fn price(net: &str, preset: &str, mapper: Mapper) -> f64 {
    let spec = Spec::builtin(net).with_preset(preset).with_mapper(mapper);
    Job::new(spec)
        .expect("builtin spec resolves")
        .report()
        .expect("builtin network prices")
        .cycle_ns
}

/// One sweep cell: Poisson arrivals at `rate_rps` against a fleet with
/// the given per-device service times, under a deadline scaled to the
/// slowest device (so overload shows up as lost goodput, not just queue
/// depth).
fn run_cell(service: &[f64], policy: Policy, rate_rps: f64, requests: u64) -> FleetReport {
    let slow = service.iter().cloned().fold(0.0f64, f64::max);
    let mean = service.iter().sum::<f64>() / service.len() as f64;
    let cfg = FleetConfig {
        devices: service.len(),
        service_ns: mean,
        batch: 1,
        policy,
        seed: 0x5EED,
        requests,
        load: 1.0,
        faults: FaultSpec::none(),
        resilience: ResilienceSpec {
            deadline_ms: Some(((slow * 10.0) / 1e6).ceil().max(1.0) as u64),
            ..ResilienceSpec::default()
        },
        traffic: Some(TrafficSpec {
            kind: ArrivalKind::Poisson,
            rate_rps,
            ..TrafficSpec::default()
        }),
        service_ns_per_device: Some(service.to_vec()),
    };
    simulate_fleet(&cfg).expect("fleet config is valid")
}

/// Aggregate fleet capacity (requests/s) at batch 1: the sum of each
/// device's service rate. The sweep expresses arrival rates as multiples
/// of this.
fn capacity_rps(service: &[f64]) -> f64 {
    service.iter().map(|&s| 1e9 / s).sum()
}

fn main() {
    banner(
        "Saturation sweep",
        "open-loop arrival rate × fleet size × device mix (virtual time)",
    );
    let fast = std::env::var("PIM_BENCH_FAST").is_ok();
    let requests: u64 = if fast { 300 } else { 2500 };
    let mut b = Bencher::from_env();

    // Real per-device service times from the timing model.
    let cloud = price("mobilenet_mini", "cloud", Mapper::Paper);
    let edge = price("mobilenet_mini", "edge", Mapper::Paper);
    println!(
        "priced mobilenet_mini: cloud {:.1} µs/img, edge {:.1} µs/img\n",
        cloud / 1e3,
        edge / 1e3
    );

    let mixes: [(&str, Vec<f64>); 4] = [
        ("cloud x2", vec![cloud, cloud]),
        ("edge x2", vec![edge, edge]),
        ("mixed x2", vec![cloud, edge]),
        ("mixed x4", vec![cloud, cloud, edge, edge]),
    ];
    let rates: [f64; 6] = [0.6, 0.8, 1.0, 1.3, 1.6, 2.0];

    let mut t = Table::new(&[
        "mix", "rate/cap", "policy", "offered rps", "goodput %", "p50 ms", "p99 ms",
        "lost",
    ])
    .aligns(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right, Align::Right,
    ]);

    let mut backlog_gain: f64 = 0.0;
    let mut knee_rps: f64 = 0.0;
    for (name, service) in &mixes {
        let cap = capacity_rps(service);
        let mut low_rate_p99: Option<f64> = None;
        let mut knee_found = false;
        for &mult in &rates {
            let rate = cap * mult;
            for policy in [Policy::RoundRobin, Policy::Backlog] {
                let r = run_cell(service, policy, rate, requests);
                assert_eq!(
                    r.accounted(),
                    r.offered,
                    "{name} x{mult} {policy:?}: every offered request must \
                     reach exactly one terminal outcome"
                );
                t.row(&[
                    name.to_string(),
                    format!("{mult:.1}"),
                    format!("{policy:?}"),
                    format!("{:.0}", r.offered_rps),
                    format!("{:.1}", 100.0 * r.goodput as f64 / r.offered as f64),
                    format!("{:.3}", r.p50_us / 1e3),
                    format!("{:.3}", r.p99_us / 1e3),
                    (r.shed + r.timeouts + r.late).to_string(),
                ]);
                if policy == Policy::Backlog {
                    // Knee: first rate where the backlog fleet stops
                    // serving ≥ 95% of offered within deadline, or p99
                    // blows past 5x its low-rate value.
                    let served = r.goodput as f64 / r.offered as f64;
                    let p99_blown = low_rate_p99
                        .map(|base| base > 0.0 && r.p99_us > 5.0 * base)
                        .unwrap_or(false);
                    low_rate_p99.get_or_insert(r.p99_us);
                    if !knee_found && (served < 0.95 || p99_blown) {
                        knee_found = true;
                        println!(
                            "knee[{name}]: {:.0} rps ({mult:.1}x capacity)",
                            r.offered_rps
                        );
                        if name.starts_with("mixed x2") {
                            knee_rps = r.offered_rps;
                        }
                    }
                }
            }
            // Mixed fleets are where capability-aware routing pays: track
            // the best goodput gain of backlog over round-robin.
            if name.starts_with("mixed") {
                let rr = run_cell(service, Policy::RoundRobin, rate, requests);
                let bl = run_cell(service, Policy::Backlog, rate, requests);
                backlog_gain = backlog_gain.max(bl.goodput as f64 / rr.goodput as f64);
            }
        }
    }
    println!("{}", t.render());

    // ---- claim A: backlog beats round-robin on a mixed fleet -------------
    println!("backlog-vs-rr best goodput gain on mixed fleets: {backlog_gain:.2}x");
    if !fast {
        assert!(
            backlog_gain > 1.0,
            "backlog-aware routing must beat round-robin goodput on a mixed \
             edge/cloud fleet at >= 1 arrival rate (got {backlog_gain:.3}x)"
        );
    }

    // ---- claim B: searched-plan dispatch serves faster -------------------
    // Pick whichever generality workload the mapping search improves more.
    let (net, paper_ns, searched_ns) = ["mobilenet_mini", "tinyformer"]
        .iter()
        .map(|net| {
            let p = price(net, "cloud", Mapper::Paper);
            let s = price(net, "cloud", Mapper::Search);
            (*net, p, s)
        })
        .max_by(|a, b| (a.1 / a.2).partial_cmp(&(b.1 / b.2)).unwrap())
        .unwrap();
    println!(
        "\nsearched dispatch on {net}: paper {:.1} µs/img, searched {:.1} µs/img",
        paper_ns / 1e3,
        searched_ns / 1e3
    );
    let mut searched_speedup: f64 = 0.0;
    let paper_fleet = vec![paper_ns, paper_ns];
    let searched_fleet = vec![searched_ns, searched_ns];
    let cap = capacity_rps(&paper_fleet);
    for &mult in &[0.6, 0.8, 1.0] {
        let p = run_cell(&paper_fleet, Policy::Backlog, cap * mult, requests);
        let s = run_cell(&searched_fleet, Policy::Backlog, cap * mult, requests);
        assert!(
            s.p50_us <= p.p50_us,
            "searched dispatch must never be slower on serve p50 \
             ({net} x{mult}: searched {:.1} µs vs paper {:.1} µs)",
            s.p50_us,
            p.p50_us
        );
        searched_speedup = searched_speedup.max(p.p50_us / s.p50_us);
    }
    println!("searched serve p50 speedup on {net}: {searched_speedup:.2}x");
    if !fast {
        assert!(
            searched_speedup > 1.0,
            "searched-plan dispatch must be strictly faster on serve p50 for \
             at least one rate on {net} (got {searched_speedup:.3}x)"
        );
    }

    // ---- determinism: same seeds, same bits ------------------------------
    let once = run_cell(&mixes[2].1, Policy::Backlog, capacity_rps(&mixes[2].1), requests);
    let again = run_cell(&mixes[2].1, Policy::Backlog, capacity_rps(&mixes[2].1), requests);
    assert_eq!(once, again, "fleet replay must be bitwise reproducible");

    // ---- wall-clock targets ----------------------------------------------
    let mixed = mixes[2].1.clone();
    let mid_rate = capacity_rps(&mixed);
    b.bench_items("saturation_cell", requests as f64, || {
        run_cell(&mixed, Policy::Backlog, mid_rate, requests).completed
    });
    b.bench("searched_fleet_price", || {
        price("mobilenet_mini", "cloud", Mapper::Search).to_bits()
    });

    // ---- structural fast-mode guard --------------------------------------
    for name in REQUIRED {
        assert!(
            b.results().iter().any(|m| m.name == name),
            "required perf target `{name}` was not measured — fast mode may \
             shrink iteration counts but never skip a target"
        );
    }

    // ---- machine-readable perf record + regression gate ------------------
    let json_path = std::env::var("PIM_BENCH_JSON").unwrap_or_else(|_| {
        format!("{}/../BENCH_PERF.json", env!("CARGO_MANIFEST_DIR"))
    });
    let baseline_path =
        std::env::var("PIM_BENCH_BASELINE").unwrap_or_else(|_| json_path.clone());
    let baseline = read_baseline(&baseline_path);
    let baseline_pairs = baseline.clone().unwrap_or_default();
    write_bench_json(
        &json_path,
        "regenerate with: cargo bench --bench perf_hotpath && cargo bench \
         --bench saturation_sweep (PIM_BENCH_FAST=1 for smoke runs)",
        b.results(),
        &[
            ("backlog_goodput_gain_x", backlog_gain),
            ("searched_p50_speedup_x", searched_speedup),
            ("saturation_knee_rps", knee_rps),
        ],
        &baseline_pairs,
    )
    .expect("writing BENCH_PERF.json");
    println!("\nwrote {json_path}  (record the table in EXPERIMENTS.md §Perf)");

    match baseline {
        None => println!(
            "no perf baseline at {baseline_path} (missing or empty seed) — \
             regression gate skipped"
        ),
        Some(base) => match check_regression(&base, b.results(), 0.25) {
            Ok(()) => println!(
                "regression gate: all shared targets within +25% of {baseline_path}"
            ),
            Err(report) => {
                eprintln!("perf regression vs {baseline_path}:\n{report}");
                std::process::exit(1);
            }
        },
    }
}
