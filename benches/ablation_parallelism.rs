//! A2 — ablation: the §IV-B parallelism ↔ footprint trade-off.
//!
//! Folding factor k shrinks the resident operand footprint ≈ k× but
//! serializes k rounds per image. This bench sweeps k per network and
//! prints both sides of the trade (the discussion around Fig 12).

use pim_dram::bench_harness::banner;
use pim_dram::mapping::footprint::resident_bits_at_k;
use pim_dram::sim::{simulate, SimConfig};
use pim_dram::util::si;
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets::all_networks;

fn main() {
    banner("Ablation A2", "parallelism k vs footprint vs throughput");
    for net in all_networks() {
        let fat = net
            .layers
            .iter()
            .max_by_key(|l| l.num_macs() * l.mac_size())
            .unwrap();
        let mut t = Table::new(&[
            "k", "img/s", "ms/img", "fat-layer resident bits", "rounds(fat)",
        ])
        .aligns(&[
            Align::Right, Align::Right, Align::Right, Align::Right, Align::Right,
        ]);
        let mut prev_ips = f64::INFINITY;
        for k in [1usize, 2, 4, 8, 16] {
            let cfg = SimConfig::paper_favorable(8).with_ks(vec![k]);
            let r = match simulate(&net, &cfg) {
                Ok(r) => r,
                Err(_) => continue, // k > outer count on a head layer
            };
            let fat_sim = r
                .layers
                .iter()
                .max_by(|a, b| {
                    (a.mapping.macs_total * a.mapping.mac_size)
                        .cmp(&(b.mapping.macs_total * b.mapping.mac_size))
                })
                .unwrap();
            let ips = r.replica_throughput_ips();
            t.row(&[
                k.to_string(),
                format!("{ips:.0}"),
                format!("{:.3}", r.pipeline.cycle_ns / 1e6),
                format!("{}b", si(resident_bits_at_k(fat, 8, k) as f64)),
                fat_sim.mapping.rounds().to_string(),
            ]);
            assert!(ips <= prev_ips + 1e-9, "{}: k must not speed up", net.name);
            prev_ips = ips;
        }
        println!("network: {}\n{}", net.name, t.render());
    }
    println!("higher k → linearly smaller footprint, linearly more rounds.");
}
