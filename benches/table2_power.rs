//! E5 — Table II: power breakdown of the bank peripheral logic,
//! calibrated to the paper's published numbers (adder tree ≈95.9 %).

use pim_dram::bench_harness::banner;
use pim_dram::energy;

fn main() {
    banner("Table II", "Power breakdown (65 nm, 4096-input adder tree)");
    println!("{}", energy::render_power_table(4096));

    let comps = energy::bank_components(4096);
    let total: f64 = comps.iter().map(|c| c.power_nw).sum();
    println!("total component power: {:.1} µW", total / 1e3);
    println!(
        "derated logic clock: {:.2} ns/cycle (nominal {:.0} MHz × {:.3} \
         DRAM-process factor [17])",
        energy::logic_cycle_ns(),
        energy::LOGIC_CLOCK_GHZ * 1e3,
        energy::DRAM_PROCESS_DELAY_FACTOR
    );

    assert!((comps[0].power_nw - 13_200_190.9).abs() < 0.1);
    assert!((comps[1].power_nw - 177_765.864).abs() < 1e-6);
    assert!((comps[5].power_nw - 28_366.738).abs() < 1e-6);
    let adder_pct = 100.0 * comps[0].power_nw / total;
    assert!(
        (adder_pct - 95.9014).abs() < 0.01,
        "adder power share {adder_pct:.4}% (paper: 95.9014%)"
    );
    println!("\nvalues match Table II; adder share {adder_pct:.4}%");
}
