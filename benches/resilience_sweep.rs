//! Resilience sweep: degraded-mode SLOs across fault intensity × retry
//! budget, on the deterministic virtual-time fleet (`coordinator::chaos`).
//!
//! Every cell replays the same 4-device fleet under a seed-pinned fault
//! schedule — transient rate swept 0 → 30%, with a straggler/storm mix
//! and a crash-and-recover window on device 0 — at three retry budgets.
//! Because the simulation is virtual-time, the numbers are bitwise
//! reproducible run-to-run; the sweep is a *report* (goodput vs offered
//! load, tail latency, shed/failed accounting), and the monotonic shape
//! targets double as regression assertions:
//!
//!   * Every cell accounts for every offered request (no silent drops).
//!   * At a fixed fault rate, retries never reduce goodput (modulo a few
//!     requests of schedule-reshuffle noise).
//!   * With retries, the fleet holds ≥ 90% goodput through 10% transients
//!     plus the crash window.

use pim_dram::bench_harness::banner;
use pim_dram::coordinator::{
    simulate_fleet, CrashSpec, FaultSpec, FleetConfig, FleetReport, Policy,
    ResilienceSpec, StormSpec, StragglerSpec,
};
use pim_dram::util::table::{Align, Table};

fn run(transient: f64, retries: u32, requests: u64) -> FleetReport {
    let cfg = FleetConfig {
        devices: 4,
        service_ns: 1_000_000.0,
        batch: 4,
        policy: Policy::RoundRobin,
        seed: 0x5EED,
        requests,
        load: 0.9,
        faults: FaultSpec {
            seed: 0xC4A05,
            transient,
            straggler: Some(StragglerSpec { prob: 0.05, factor: 3.0 }),
            storm: Some(StormSpec { period: 32, duty: 4, factor: 2.0 }),
            crash: vec![CrashSpec { device: 0, after: 10, down_for: Some(12) }],
        },
        resilience: ResilienceSpec {
            retries,
            quarantine_after: 2,
            probe_after_ms: 10,
            ..ResilienceSpec::default()
        },
        traffic: None,
        service_ns_per_device: None,
    };
    simulate_fleet(&cfg).expect("fleet config is valid")
}

fn main() {
    banner(
        "Resilience sweep",
        "fault intensity × retry budget on the virtual-time fleet",
    );
    let requests: u64 =
        if std::env::var("PIM_BENCH_FAST").is_ok() { 400 } else { 2000 };

    let mut t = Table::new(&[
        "transient", "retries", "goodput %", "completed", "shed", "failed",
        "retried", "failover", "quarantine", "p99 ms",
    ])
    .aligns(&[
        Align::Right, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right, Align::Right, Align::Right, Align::Right,
    ]);

    for &transient in &[0.0, 0.05, 0.1, 0.2, 0.3] {
        let mut prev_goodput: Option<u64> = None;
        for &retries in &[0u32, 1, 3] {
            let r = run(transient, retries, requests);
            assert_eq!(
                r.accounted(),
                r.offered,
                "transient={transient} retries={retries}: every offered request \
                 must reach exactly one terminal outcome"
            );
            if let Some(prev) = prev_goodput {
                // Raising the retry budget reshuffles batch coordinates
                // (and thus the drawn schedule), so allow a few requests
                // of noise around the monotone trend.
                assert!(
                    r.goodput + 5 >= prev,
                    "transient={transient}: goodput fell from {prev} to {} when \
                     retries rose to {retries}",
                    r.goodput
                );
            }
            prev_goodput = Some(r.goodput);
            t.row(&[
                format!("{:.0}%", transient * 100.0),
                retries.to_string(),
                format!("{:.1}", 100.0 * r.goodput as f64 / r.offered as f64),
                r.completed.to_string(),
                r.shed.to_string(),
                r.failed.to_string(),
                r.retried.to_string(),
                r.failovers.to_string(),
                format!("{}/{}", r.quarantines, r.reintegrations),
                format!("{:.2}", r.p99_us / 1e3),
            ]);
        }
    }
    println!("{}", t.render());

    // The headline claim: a retrying fleet rides through 10% transients
    // plus a crash-and-recover window nearly unscathed.
    let degraded = run(0.1, 3, requests);
    assert!(
        degraded.goodput * 10 >= degraded.offered * 9,
        "fleet must hold >= 90% goodput at 10% transients with retries: {}",
        degraded.render()
    );
    // And the whole sweep is deterministic: same seed, same bits.
    let again = run(0.1, 3, requests);
    assert_eq!(degraded, again, "fleet replay must be bitwise reproducible");
    println!("{}", degraded.render());
    println!("shape targets hold: accounting exact, retries monotone, replay bitwise");
}
