//! E6/E8 — Fig 16: system-level speedup of PIM-DRAM over the ideal GPU
//! for AlexNet, VGG16 and ResNet18 at parallelism P1..P4, on the
//! paper-favorable configuration (resident operands, per-subarray tree
//! taps, row-wide links — DESIGN.md §7 documents why those assumptions
//! are required for the paper's numbers to be reachable).
//!
//! Shape targets: PIM wins on every network; speedup is highest at P1 and
//! decreases with the folding factor; peak ≈ O(10×) (paper: up to 19.5×).

use pim_dram::bench_harness::{banner, Bencher};
use pim_dram::gpu::GpuModel;
use pim_dram::sim::{simulate, SimConfig};
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets::all_networks;

fn main() {
    banner("Fig 16", "PIM-DRAM speedup over ideal TITAN Xp (P1..P4)");
    let gpu = GpuModel::titan_xp();
    // The paper's P-vectors: P1=(1,..), P2=(2,..), P3=(4,..), P4=(8,..).
    let p_factors = [1usize, 2, 4, 8];

    for bits in [8usize, 4] {
        let mut t = Table::new(&["network", "GPU ms", "P1", "P2", "P3", "P4"])
            .aligns(&[
                Align::Left, Align::Right, Align::Right, Align::Right,
                Align::Right, Align::Right,
            ]);
        let mut peak: f64 = 0.0;
        for net in all_networks() {
            let gpu_ms = gpu.network_time_s(&net, 4) * 1e3;
            let mut row = vec![net.name.clone(), format!("{gpu_ms:.3}")];
            for &k in &p_factors {
                let cfg = SimConfig::paper_favorable(bits).with_ks(vec![k]);
                let r = simulate(&net, &cfg).expect("simulate");
                let s = r.speedup_vs(&gpu, &net, 4);
                peak = peak.max(s);
                row.push(format!("{s:.2}x"));
            }
            t.row(&row);
        }
        println!("operand precision: {bits}-bit\n{}", t.render());
        println!("peak speedup at {bits}-bit: {peak:.1}x (paper headline: 19.5x)\n");
        if bits == 4 {
            assert!(peak > 10.0, "4-bit peak should reach the paper's order");
        }
    }

    // Shape assertions at 8-bit: every network wins, P1 ≥ P4.
    for net in all_networks() {
        let s1 = simulate(&net, &SimConfig::paper_favorable(8))
            .unwrap()
            .speedup_vs(&gpu, &net, 4);
        let s4 = simulate(&net, &SimConfig::paper_favorable(8).with_ks(vec![8]))
            .unwrap()
            .speedup_vs(&gpu, &net, 4);
        assert!(s1 > 1.0, "{}: PIM must beat the ideal GPU (got {s1:.2})", net.name);
        assert!(s1 >= s4, "{}: speedup must not grow with folding", net.name);
    }
    println!("shape checks passed: all networks win; P1 >= P4.");

    let mut b = Bencher::from_env();
    let vgg = pim_dram::workloads::nets::vgg16();
    b.bench("simulate(vgg16, paper_favorable 8b)", || {
        simulate(&vgg, &SimConfig::paper_favorable(8)).unwrap().total_aaps
    });
}
