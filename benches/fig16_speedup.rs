//! E6/E8 — Fig 16: system-level speedup of PIM-DRAM over the ideal GPU
//! for AlexNet, VGG16 and ResNet18 at parallelism P1..P4, on the
//! paper-favorable configuration (resident operands, per-subarray tree
//! taps, row-wide links — DESIGN.md §7 documents why those assumptions
//! are required for the paper's numbers to be reachable).
//!
//! Shape targets: PIM wins on every network; speedup is highest at P1 and
//! decreases with the folding factor; peak ≈ O(10×) (paper: up to 19.5×).
//!
//! Sweep machinery (DESIGN.md §8/§API): every point is an `api::Spec`
//! variant run through one `api::Job` per network; networks run on all
//! cores via `par_sweep`, and each network's P1..P4 points share the
//! job's incremental session so only the lowering/aggregation re-runs.

use pim_dram::api::{Job, Spec};
use pim_dram::bench_harness::{banner, par_sweep, Bencher};
use pim_dram::gpu::GpuModel;
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets::paper_networks;

fn main() {
    banner("Fig 16", "PIM-DRAM speedup over ideal TITAN Xp (P1..P4)");
    let gpu = GpuModel::titan_xp();
    let nets = paper_networks();
    // The paper's P-vectors: P1=(1,..), P2=(2,..), P3=(4,..), P4=(8,..).
    let p_factors = [1usize, 2, 4, 8];

    for bits in [8usize, 4] {
        // One parallel worker per network; P-points sweep incrementally.
        let rows = par_sweep(nets.len(), |i| {
            let net = &nets[i];
            let base = Spec::builtin(&net.name)
                .with_preset("paper_favorable")
                .with_precision(bits);
            let job = Job::new(base.clone()).expect("spec resolves");
            let mut session = job.session();
            let gpu_ms = gpu.network_time_s(net, 4) * 1e3;
            let speedups: Vec<f64> = p_factors
                .iter()
                .map(|&k| {
                    job.report_variant(&mut session, &base.clone().with_ks(vec![k]))
                        .expect("simulate")
                        .speedup_vs(&gpu, net, 4)
                })
                .collect();
            (net.name.clone(), gpu_ms, speedups)
        });

        let mut t = Table::new(&["network", "GPU ms", "P1", "P2", "P3", "P4"])
            .aligns(&[
                Align::Left, Align::Right, Align::Right, Align::Right,
                Align::Right, Align::Right,
            ]);
        let mut peak: f64 = 0.0;
        for (name, gpu_ms, speedups) in &rows {
            let mut row = vec![name.clone(), format!("{gpu_ms:.3}")];
            for &s in speedups {
                peak = peak.max(s);
                row.push(format!("{s:.2}x"));
            }
            t.row(&row);
        }
        println!("operand precision: {bits}-bit\n{}", t.render());
        println!("peak speedup at {bits}-bit: {peak:.1}x (paper headline: 19.5x)\n");
        if bits == 4 {
            assert!(peak > 10.0, "4-bit peak should reach the paper's order");
        }

        // Shape assertions at 8-bit, straight from the sweep rows:
        // every network wins, P1 ≥ P4.
        if bits == 8 {
            for (name, _, speedups) in &rows {
                let (s1, s4) = (speedups[0], speedups[3]);
                assert!(s1 > 1.0, "{name}: PIM must beat the ideal GPU (got {s1:.2})");
                assert!(s1 >= s4, "{name}: speedup must not grow with folding");
            }
            println!("shape checks passed: all networks win; P1 >= P4.\n");
        }
    }

    let mut b = Bencher::from_env();
    let job = Job::new(Spec::builtin("vgg16").with_preset("paper_favorable"))
        .expect("spec resolves");
    b.bench("Job::report(vgg16, paper_favorable 8b)", || {
        job.report().unwrap().total_aaps
    });
    let mut session = job.session();
    b.bench("session.report(vgg16, paper_favorable 8b)", || {
        session.report(job.config()).unwrap().total_aaps
    });
}
