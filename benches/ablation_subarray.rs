//! A3 — ablation: subarray capacity per bank — the assumption audit.
//!
//! The paper's Fig 16 numbers implicitly require each layer's operand
//! expansion to be resident (DESIGN.md §7). This bench walks capacity
//! from the paper-ideal budget down to a real DDR3 die (32 subarrays/bank)
//! and shows where the speedup collapses into restaging waves.

use pim_dram::bench_harness::banner;
use pim_dram::gpu::GpuModel;
use pim_dram::sim::{simulate, SimConfig};
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets::{alexnet, vgg16};

fn main() {
    banner("Ablation A3", "subarrays/bank: paper-ideal → real DDR3");
    let gpu = GpuModel::titan_xp();
    for net in [alexnet(), vgg16()] {
        let gpu_ms = gpu.network_time_s(&net, 4) * 1e3;
        let mut t = Table::new(&[
            "subarrays/bank", "resident", "max waves", "ms/img", "speedup",
        ])
        .aligns(&[
            Align::Right, Align::Right, Align::Right, Align::Right, Align::Right,
        ]);
        let mut speeds = Vec::new();
        for subs in [1usize << 20, 65536, 4096, 512, 32] {
            let mut cfg = SimConfig::paper_favorable(8);
            cfg.geometry.subarrays_per_bank = subs;
            let r = simulate(&net, &cfg).unwrap();
            let resident = r.layers.iter().all(|l| l.mapping.fully_resident());
            let max_waves =
                r.layers.iter().map(|l| l.mapping.waves).max().unwrap();
            let s = r.speedup_vs(&gpu, &net, 4);
            speeds.push(s);
            t.row(&[
                subs.to_string(),
                resident.to_string(),
                max_waves.to_string(),
                format!("{:.3}", r.pipeline.cycle_ns / 1e6),
                format!("{s:.3}x"),
            ]);
        }
        println!("network: {} (ideal GPU: {gpu_ms:.3} ms)\n{}", net.name, t.render());
        assert!(
            speeds.first().unwrap() > speeds.last().unwrap(),
            "{}: shrinking capacity must hurt",
            net.name
        );
        assert!(
            *speeds.last().unwrap() < 1.0,
            "{}: at real DDR3 capacity the headline should invert \
             (that's the finding)",
            net.name
        );
    }
    println!(
        "finding: the 19.5x-class speedups need the operand expansion to be\n\
         resident; at a real DDR3 die's 32 subarrays/bank, restaging waves\n\
         dominate and the ideal GPU wins. See EXPERIMENTS.md discussion."
    );
}
