//! E3 — Fig 15: Monte Carlo robustness of the AND primitive, 100 000
//! samples per input case (the paper's count). Prints the pre-sense
//! bitline histograms and the sense-margin statistic the paper reports
//! (mean ≈ 200 mV), plus the failure count.

use pim_dram::bench_harness::{banner, Bencher};
use pim_dram::circuit::{run_monte_carlo, CircuitParams};

fn main() {
    banner("Fig 15", "Monte Carlo of the AND bitline (100k samples/case)");
    let p = CircuitParams::cmos65nm();
    let samples = if std::env::var("PIM_BENCH_FAST").is_ok() {
        10_000
    } else {
        100_000
    };
    let mc = run_monte_carlo(&p, samples, 0xF1615);

    for (inputs, hist) in &mc.histograms {
        println!(
            "case ({}) — pre-sense BL histogram (V):",
            inputs.label()
        );
        println!("{}", hist.ascii(40));
    }
    for (inputs, s) in &mc.case_summaries {
        println!(
            "case ({}): mean {:.4} V, σ {:.4} V, [{:.4}, {:.4}]",
            inputs.label(),
            s.mean(),
            s.std(),
            s.min(),
            s.max()
        );
    }
    println!(
        "\nsense margin: {:.1} mV mean (paper: ≈200 mV); worst-case sample \
         margin {:.1} mV; failures {} / {} ({:.2e})",
        mc.sense_margin_v * 1e3,
        mc.worst_margin_v * 1e3,
        mc.failures,
        samples * 4,
        mc.failure_rate()
    );
    assert!((mc.sense_margin_v - 0.2).abs() < 0.02, "margin off paper value");
    assert_eq!(mc.failures, 0, "AND must be robust at nominal variation");

    let mut b = Bencher::from_env();
    b.bench_items("monte_carlo 4x10k samples", 40_000.0, || {
        run_monte_carlo(&p, 10_000, 1).failures
    });
}
