//! A4 — ablation: the `pim::mapopt` mapping search vs the paper mapping.
//!
//! For every builtin network on the conservative die, price the paper
//! mapping (Algorithm 1 at the spec's k) and the searched mapping (beam
//! search over k × tiling × data layout, `mapopt::optimize`) through one
//! shared session, and compare end-to-end latency. The search carries a
//! never-worse guarantee, asserted here on every network; on networks
//! with non-resident layers whose staging the tiling/layout knobs can
//! restructure (mobilenet_mini, tinyformer) the win must be strict.
//!
//! Also times the search itself (cold session per iteration) so the
//! perf suite sees regressions in candidate enumeration or pruning.

use pim_dram::bench_harness::{banner, black_box, Bencher};
use pim_dram::mapopt::{optimize, SearchKnobs};
use pim_dram::sim::{SimConfig, SimSession};
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets::all_networks;

fn main() {
    banner("Ablation A4", "mapping search (k x tiling x layout) vs paper mapping");

    let mut t = Table::new(&[
        "network", "paper ms", "searched ms", "gain%", "changed", "priced", "pruned",
    ])
    .aligns(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right,
    ]);
    let mut total_priced = 0usize;
    let mut total_changed = 0usize;
    let mut total_layers = 0usize;
    for net in all_networks() {
        let cfg = SimConfig::conservative(8);
        let mut session = SimSession::new(&net);
        let out = optimize(&mut session, &cfg, &SearchKnobs::default())
            .unwrap_or_else(|e| panic!("{}: search failed: {e}", net.name));

        // The contract the optimizer ships with: never worse, anywhere.
        assert!(
            out.searched.latency_ns <= out.paper.latency_ns,
            "{}: searched {} ns > paper {} ns",
            net.name,
            out.searched.latency_ns,
            out.paper.latency_ns
        );
        for c in &out.choices {
            assert!(
                c.stage_ns <= c.paper_stage_ns,
                "{}/{}: chosen stage worse than paper",
                net.name,
                c.name
            );
        }
        // Strict end-to-end wins where the staging knobs have room.
        if net.name == "mobilenet_mini" || net.name == "tinyformer" {
            assert!(
                out.improved(),
                "{}: expected a strict latency win, got paper {} ns vs searched {} ns",
                net.name,
                out.paper.latency_ns,
                out.searched.latency_ns
            );
            assert!(!out.fell_back, "{}: unexpected end-to-end fallback", net.name);
        }

        total_priced += out.candidates_priced;
        total_changed += out.changed_layers();
        total_layers += net.layers.len();
        t.row(&[
            net.name.clone(),
            format!("{:.3}", out.paper.latency_ns / 1e6),
            format!("{:.3}", out.searched.latency_ns / 1e6),
            format!(
                "{:.2}",
                100.0 * (out.paper.latency_ns - out.searched.latency_ns)
                    / out.paper.latency_ns
            ),
            format!("{}/{}", out.changed_layers(), out.choices.len()),
            out.candidates_priced.to_string(),
            out.pruned_branches.to_string(),
        ]);
    }
    println!("{}", t.render());

    // Structural guard (CI greps this line): the run only counts if the
    // search actually explored beyond the paper mapping and changed
    // something.
    assert!(
        total_priced > total_layers,
        "search priced nothing beyond the paper candidates"
    );
    assert!(total_changed > 0, "search never improved a layer");
    println!(
        "structural: search exercised — {total_priced} candidate(s) priced across \
         {total_layers} layer(s), {total_changed} layer mapping(s) changed"
    );

    // Search cost itself (cold session per iteration — enumeration,
    // bounding, pruning and exact pricing all included).
    let mut b = Bencher::from_env();
    let vgg = all_networks().into_iter().find(|n| n.name == "vgg16").unwrap();
    let cfg = SimConfig::conservative(8);
    b.bench("mapopt::optimize(vgg16, cold)", || {
        let mut session = SimSession::new(&vgg);
        black_box(optimize(&mut session, &cfg, &SearchKnobs::default()).unwrap())
    });
    // Warm arena: the sweep's steady state (every candidate cached).
    let mut warm = SimSession::new(&vgg);
    optimize(&mut warm, &cfg, &SearchKnobs::default()).unwrap();
    b.bench("mapopt::optimize(vgg16, warm)", || {
        black_box(optimize(&mut warm, &cfg, &SearchKnobs::default()).unwrap())
    });
}
