//! S1 — scale-out sweep: the device-plan layer across the channel × rank
//! grid (the serving story the ROADMAP's north star needs — multi-module
//! PIM deployments à la Oliveira et al. / Gómez-Luna et al.).
//!
//! Sweeps channels 1..8 for every network under the three shard policies
//! and reports replicas, aggregate throughput, per-image latency and the
//! priced inter-channel hop cost. Every point is an `api::Spec` variant
//! (grid + shard) run through one `api::Job` per network; networks sweep
//! on all cores (`par_sweep`), and the grid/shard axes are exactly what
//! the job's session cache is invariant to, so only the lowering re-runs
//! per point.
//!
//! Shape targets checked:
//!   * Replicate: aggregate throughput scales exactly linearly with the
//!     replica count; latency does not move.
//!   * LayerSplit: latency strictly grows (hops are priced, not ignored),
//!     while the steady-state cycle never degrades (per-channel buses).

use pim_dram::api::{Job, Spec};
use pim_dram::bench_harness::{banner, par_sweep, Bencher};
use pim_dram::plan::ShardPolicy;
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets::all_networks;

fn main() {
    banner("Scale-out S1", "channels × ranks sharding sweep (conservative)");
    let nets = all_networks();

    let reports = par_sweep(nets.len(), |ni| {
        let net = &nets[ni];
        let base = Spec::builtin(&net.name).with_preset("conservative");
        let job = Job::new(base.clone()).expect("spec resolves");
        let mut session = job.session();
        let base_r = job.report_variant(&mut session, &base).expect("simulate");
        let mut t = Table::new(&[
            "channels", "policy", "replicas", "devices", "img/s", "ms/img",
            "hops us/img",
        ])
        .aligns(&[
            Align::Right, Align::Left, Align::Right, Align::Right, Align::Right,
            Align::Right, Align::Right,
        ]);

        let mut prev_ips = 0.0f64;
        for channels in [1usize, 2, 4, 8] {
            // Replicate
            let r = job
                .report_variant(&mut session, &base.clone().with_grid(channels, 4))
                .expect("simulate");
            assert!(
                r.throughput_ips() >= prev_ips,
                "{}: replicate throughput must grow with channels",
                net.name
            );
            assert!(
                (r.latency_ns - base_r.latency_ns).abs() < 1e-6 * base_r.latency_ns,
                "{}: replication must not move latency",
                net.name
            );
            let per_replica = r.replica_throughput_ips();
            assert!(
                (r.throughput_ips() - r.replicas as f64 * per_replica).abs()
                    < 1e-9 * r.throughput_ips(),
                "{}: aggregate must be replicas × per-replica",
                net.name
            );
            prev_ips = r.throughput_ips();
            t.row(&[
                channels.to_string(),
                "replicate".into(),
                r.replicas.to_string(),
                r.devices_total().to_string(),
                format!("{:.1}", r.throughput_ips()),
                format!("{:.3}", r.latency_ns / 1e6),
                "-".into(),
            ]);

            // LayerSplit (needs ≥ 2 channels to split anything).
            if channels >= 2 {
                let r = job
                    .report_variant(
                        &mut session,
                        &base
                            .clone()
                            .with_grid(channels, 4)
                            .with_shard(ShardPolicy::LayerSplit),
                    )
                    .expect("simulate");
                assert!(
                    r.latency_ns > base_r.latency_ns,
                    "{}: layer-split must pay inter-channel hops",
                    net.name
                );
                assert!(
                    r.cycle_ns <= base_r.cycle_ns * 1.001,
                    "{}: per-channel buses must not slow the cycle",
                    net.name
                );
                t.row(&[
                    channels.to_string(),
                    "layersplit".into(),
                    r.replicas.to_string(),
                    r.devices_total().to_string(),
                    format!("{:.1}", r.throughput_ips()),
                    format!("{:.3}", r.latency_ns / 1e6),
                    format!("{:.1}", r.hop_ns_total / 1e3),
                ]);

                // Hybrid: half the channels replicate, each half splits.
                let r = job
                    .report_variant(
                        &mut session,
                        &base
                            .clone()
                            .with_grid(channels, 4)
                            .with_shard(ShardPolicy::Hybrid { replicas: channels / 2 }),
                    )
                    .expect("simulate");
                assert_eq!(r.replicas, channels / 2);
                t.row(&[
                    channels.to_string(),
                    format!("hybrid:{}", channels / 2),
                    r.replicas.to_string(),
                    r.devices_total().to_string(),
                    format!("{:.1}", r.throughput_ips()),
                    format!("{:.3}", r.latency_ns / 1e6),
                    format!("{:.1}", r.hop_ns_total / 1e3),
                ]);
            }
        }
        let (hits, misses) = session.cache_stats();
        format!(
            "network: {}\n{}(session cache: {hits} hits / {misses} misses)\n",
            net.name,
            t.render()
        )
    });
    for report in reports {
        println!("{report}");
    }
    println!(
        "replication scales throughput linearly at flat latency; layer-split \
         trades hop latency for per-channel bus relief."
    );

    // Wall-clock cost of the plan→price→aggregate path itself.
    let mut b = Bencher::from_env();
    let spec = Spec::builtin("resnet18")
        .with_preset("conservative")
        .with_grid(8, 4)
        .with_shard(ShardPolicy::Hybrid { replicas: 4 });
    let job = Job::new(spec).expect("spec resolves");
    b.bench("Job::report(resnet18, hybrid:4 over 8ch)", || {
        job.report().unwrap().devices_total()
    });
    let mut session = job.session();
    b.bench("session.report(resnet18, hybrid:4 over 8ch)", || {
        session.report(job.config()).unwrap().devices_total()
    });
}
