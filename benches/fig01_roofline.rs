//! E1 — Fig 1: Titan Xp roofline for VGG16.
//!
//! Regenerates the figure's data: every VGG16 layer placed on the Titan Xp
//! roofline (operational intensity vs attainable GFLOP/s). The paper's
//! claim to check: *some* layers (the FC block) sit left of the ridge —
//! memory bound — motivating PIM.

use pim_dram::bench_harness::{banner, Bencher};
use pim_dram::gpu::{roofline::roofline_points, GpuModel};
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets::vgg16;

fn main() {
    banner("Fig 1", "TITAN Xp roofline for VGG16");
    let gpu = GpuModel::titan_xp();
    let net = vgg16();
    let points = roofline_points(&gpu, &net, 4);

    let mut t = Table::new(&["layer", "FLOP/byte", "attainable GFLOP/s", "bound"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Left]);
    let mut mem_bound = Vec::new();
    for p in &points {
        t.row(&[
            p.layer.clone(),
            format!("{:.2}", p.op_intensity),
            format!("{:.1}", p.attainable_gflops),
            if p.memory_bound { "MEMORY".into() } else { "compute".into() },
        ]);
        if p.memory_bound {
            mem_bound.push(p.layer.as_str());
        }
    }
    println!("{}", t.render());
    println!(
        "ridge point: {:.1} FLOP/byte (peak {:.2} TFLOP/s / {:.1} GB/s)",
        gpu.ridge_intensity(),
        gpu.peak_flops / 1e12,
        gpu.mem_bw / 1e9
    );
    println!("memory-bound layers: {mem_bound:?}");
    assert!(
        mem_bound.contains(&"fc6") && mem_bound.contains(&"fc7"),
        "paper's premise: VGG16 FC layers are memory bound"
    );

    let mut b = Bencher::from_env();
    b.bench("roofline_points(vgg16)", || {
        roofline_points(&gpu, &net, 4).len()
    });
}
