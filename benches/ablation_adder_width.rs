//! A1 — ablation: reconfigurable adder-tree width.
//!
//! The tree is 99+ % of peripheral area (Table I), so its width is the
//! design's main area knob. Sweep 512..8192 inputs and report area, power,
//! and VGG16 throughput — the area/throughput trade the paper's §IV-A.1
//! design point (4096) sits on.

use pim_dram::bench_harness::banner;
use pim_dram::energy;
use pim_dram::gpu::GpuModel;
use pim_dram::sim::{simulate, SimConfig};
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets::vgg16;

fn main() {
    banner("Ablation A1", "adder-tree width: area/power vs throughput");
    let net = vgg16();
    let gpu = GpuModel::titan_xp();
    let mut t = Table::new(&[
        "inputs", "units", "area mm^2", "power mW", "vgg16 ms/img", "speedup",
    ])
    .aligns(&[
        Align::Right, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right,
    ]);
    let mut prev_ms = f64::INFINITY;
    for inputs in [512usize, 1024, 2048, 4096, 8192] {
        let mut cfg = SimConfig::paper_favorable(8);
        cfg.adder_inputs = inputs;
        let r = simulate(&net, &cfg).unwrap();
        let ms = r.pipeline.cycle_ns / 1e6;
        t.row(&[
            inputs.to_string(),
            (inputs - 1).to_string(),
            format!("{:.3}", energy::adder_tree_area_um2(inputs) / 1e6),
            format!("{:.2}", energy::adder_tree_power_nw(inputs) / 1e6),
            format!("{ms:.3}"),
            format!("{:.2}x", r.speedup_vs(&gpu, &net, 4)),
        ]);
        // Monotone up to the row-buffer width; beyond it the extra pipeline
        // level adds fill latency with no more lanes to feed.
        if inputs <= 4096 {
            assert!(ms <= prev_ms + 1e-9, "wider tree must not be slower");
            prev_ms = ms;
        }
    }
    println!("{}", t.render());
    println!(
        "area scales linearly in units; throughput saturates once the tree\n\
         matches the subarray row-buffer width (4096) — the paper's design point."
    );
}
