//! E4 — Table I: area breakdown of the bank peripheral logic at 65 nm,
//! calibrated to the paper's published absolute numbers (the adder tree
//! dominates with ≈99.5 % of component area).

use pim_dram::bench_harness::banner;
use pim_dram::energy;

fn main() {
    banner("Table I", "Area breakdown (65 nm, 4096-input adder tree)");
    println!("{}", energy::render_area_table(4096));

    let comps = energy::bank_components(4096);
    let total: f64 = comps.iter().map(|c| c.area_um2).sum();
    println!("total component area: {total:.0} µm²");
    println!(
        "transpose unit (256×8 SRAM): {:.3} µm² (paper §IV-A.6)",
        energy::transpose_area_um2(256, 8)
    );
    println!(
        "whole-bank peripheral area: {:.0} µm²",
        energy::bank_peripheral_area_um2(4096)
    );

    // Paper-exact absolute values.
    assert_eq!(comps[0].area_um2, 514_877.0);
    assert_eq!(comps[1].area_um2, 804.0);
    assert_eq!(comps[5].area_um2, 91.0);
    let adder_pct = 100.0 * comps[0].area_um2 / total;
    assert!(
        (adder_pct - 99.47).abs() < 0.05,
        "adder area share {adder_pct:.3}% (paper: 99.47373%)"
    );
    println!("\nvalues match Table I; adder share {adder_pct:.3}%");
    println!(
        "(note: the paper's printed percentages are internally inconsistent \
         by ~0.02% — DESIGN.md §7)"
    );
}
